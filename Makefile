# Tier-1 verify and smoke benchmarks in one command each.
PY ?= python

.PHONY: test bench-smoke bench

test:
	$(PY) -m pytest -x -q

# Fast perf record: mixed-contract bytecode block through one jitted executor.
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.engine_bench --workload mixed --fast

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast

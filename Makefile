# Tier-1 verify and smoke benchmarks in one command each.
PY ?= python

.PHONY: test test-fast test-dist test-guard bench-smoke bench \
	bench-baselines bench-shards bench-hotpath bench-dist bench-guard \
	profile report check-regression check-regression-dist \
	check-regression-guard

test:
	$(PY) -m pytest -x -q

# Tier-1 subset: no hypothesis search — property tests draw at most 2
# deterministic examples each (see tests/_hypo.py).
test-fast:
	REPRO_FAST_EXAMPLES=2 $(PY) -m pytest -x -q

# Multi-device suite directly on an 8-virtual-device CPU mesh (the flag must
# reach XLA before jax initializes; plain `make test` covers the same suite
# through tests/test_dist.py's subprocess runner instead).
test-dist:
	REPRO_FAST_EXAMPLES=2 JAX_PLATFORMS=cpu \
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m pytest -x -q tests/test_dist.py

# Chaos / guard / degradation property suite directly on the 8-device mesh
# (same flag contract as test-dist; plain `make test` covers it through
# tests/test_guard.py's subprocess runner instead).
test-guard:
	REPRO_FAST_EXAMPLES=2 JAX_PLATFORMS=cpu \
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m pytest -x -q tests/test_guard.py

# Fast perf record: mixed-contract bytecode block through one jitted executor.
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.engine_bench --workload mixed --fast

# Four-engine comparison grid (sequential/Block-STM/Bohm/LiTM on mixed
# blocks) + branch-free-ALU A/B -> BENCH_baselines.json.
bench-baselines:
	PYTHONPATH=src $(PY) -m benchmarks.engine_bench --workload baselines --fast

# Sharded MV backend grid (n_locs x n_shards x zipf_s, up to 10M locations)
# -> BENCH_shards.json.
bench-shards:
	PYTHONPATH=src $(PY) -m benchmarks.engine_bench --workload shards --fast

# Wave hot-loop phase timings: incremental backend.update vs full rebuild
# per wave (+ end-to-end tps both ways) on the shard grid
# -> BENCH_hotpath.json (uploaded as a CI artifact).
bench-hotpath:
	PYTHONPATH=src $(PY) -m benchmarks.hotpath_bench --fast

# Multi-device per-wave phase timings over devices {1,2,8} x zipf x n_locs
# at fixed regions-per-device -> BENCH_dist.json (uploaded as a CI
# artifact).  Forces its own 8-device host platform before importing jax.
bench-dist:
	PYTHONPATH=src $(PY) -m benchmarks.dist_bench --fast

# Guard/chaos overhead on the mirrored hotpath cell: guard levels 0/1/2,
# a full chaos schedule, and the sequential degradation fallback
# -> BENCH_guard.json (cross-gated against BENCH_hotpath.json).
bench-guard:
	PYTHONPATH=src $(PY) -m benchmarks.guard_bench --fast

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast

# Perfetto profile of a representative mixed block: jax.profiler.trace dump
# under profiles/ with the engine's blockstm.* named scopes labelling the
# phases (open the .trace.json.gz at https://ui.perfetto.dev).
profile:
	PYTHONPATH=src $(PY) -m repro.obs.profile --out profiles

# Wave-table / abort-chain report over WAVE_TRACE.json.  Generate the trace
# (plus CHROME_TRACE.json for perfetto) with:
#   PYTHONPATH=src python -m benchmarks.engine_bench --workload mixed --trace
report:
	PYTHONPATH=src $(PY) -m repro.obs.report WAVE_TRACE.json

# The CI perf gate, locally: fresh hotpath record vs the committed baseline
# (fails only on order-of-magnitude regressions).
check-regression:
	PYTHONPATH=src $(PY) -m benchmarks.hotpath_bench --fast \
		--out BENCH_hotpath.fresh.json
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
		BENCH_hotpath.fresh.json

# Same gate for the multi-device record (throughput in the 10x band plus the
# execute partition's exact lanes/routed-bytes-per-device structure).
check-regression-dist:
	PYTHONPATH=src $(PY) -m benchmarks.dist_bench --fast \
		--out BENCH_dist.fresh.json
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
		BENCH_dist.fresh.json

# Guard gate: fresh guard record vs the committed BENCH_guard.json, plus
# the tps_guard0 cross-check against the committed hotpath cell.
check-regression-guard:
	PYTHONPATH=src $(PY) -m benchmarks.guard_bench --fast \
		--out BENCH_guard.fresh.json
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
		BENCH_guard.fresh.json

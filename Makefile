# Tier-1 verify and smoke benchmarks in one command each.
PY ?= python

# Every registered suite (incl. dist) needs the 8-virtual-device host
# platform; the flag must reach XLA before jax initializes its backend.
BENCH_ENV = JAX_PLATFORMS=cpu \
	XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src

.PHONY: test test-fast test-dist test-guard bench-smoke bench \
	bench-bytecode bench-baselines bench-shards bench-hotpath bench-dist \
	bench-guard profile report dashboard check-regression-all

test:
	$(PY) -m pytest -x -q

# Tier-1 subset: no hypothesis search — property tests draw at most 2
# deterministic examples each (see tests/_hypo.py).
test-fast:
	REPRO_FAST_EXAMPLES=2 $(PY) -m pytest -x -q

# Multi-device suite directly on an 8-virtual-device CPU mesh (the flag must
# reach XLA before jax initializes; plain `make test` covers the same suite
# through tests/test_dist.py's subprocess runner instead).
test-dist:
	REPRO_FAST_EXAMPLES=2 JAX_PLATFORMS=cpu \
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m pytest -x -q tests/test_dist.py

# Chaos / guard / degradation property suite directly on the 8-device mesh
# (same flag contract as test-dist; plain `make test` covers it through
# tests/test_guard.py's subprocess runner instead).
test-guard:
	REPRO_FAST_EXAMPLES=2 JAX_PLATFORMS=cpu \
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m pytest -x -q tests/test_guard.py

# Fast smoke: mixed-contract bytecode block through one jitted executor
# (no record emitted — the full suite is bench-bytecode).
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.engine_bench --workload mixed --fast

# One registered suite each, through the shared registry harness
# (benchmarks/registry.py): regenerates the repo-root BENCH_<suite>.json
# baseline and appends a commit-stamped BENCH_HISTORY.jsonl line.
bench-bytecode:
	$(BENCH_ENV) $(PY) -m benchmarks.registry run bytecode --fast

bench-baselines:
	$(BENCH_ENV) $(PY) -m benchmarks.registry run baselines --fast

bench-shards:
	$(BENCH_ENV) $(PY) -m benchmarks.registry run shards --fast

bench-hotpath:
	$(BENCH_ENV) $(PY) -m benchmarks.registry run hotpath --fast

bench-dist:
	$(BENCH_ENV) $(PY) -m benchmarks.registry run dist --fast

bench-guard:
	$(BENCH_ENV) $(PY) -m benchmarks.registry run guard --fast

# Every registered suite under one harness and one host platform (the
# 8-device mesh, so the dist suite is included and all records carry the
# same env stamp).
bench:
	$(BENCH_ENV) $(PY) -m benchmarks.registry run --all --fast

# Perfetto profile of a representative mixed block: jax.profiler.trace dump
# under profiles/ with the engine's blockstm.* named scopes labelling the
# phases (open the .trace.json.gz at https://ui.perfetto.dev).
profile:
	PYTHONPATH=src $(PY) -m repro.obs.profile --out profiles

# Wave-table / abort-chain report over WAVE_TRACE.json.  Generate the trace
# (plus CHROME_TRACE.json for perfetto) with:
#   PYTHONPATH=src python -m benchmarks.engine_bench --workload mixed --trace
report:
	PYTHONPATH=src $(PY) -m repro.obs.report WAVE_TRACE.json

# Cross-commit perf-trajectory trend tables over BENCH_HISTORY.jsonl (one
# git-SHA-stamped line per registry suite run).
dashboard:
	PYTHONPATH=src $(PY) -m repro.obs.report --history

# The CI perf gate, locally: measure a fresh record for EVERY registered
# suite (under bench_fresh/) and gate each against its committed repo-root
# baseline by the registry's declared metrics — throughput within the 10x
# band, structural quantities exact, aggregates refused across
# incomparable runs.  Single-record usage:
#   PYTHONPATH=src python -m benchmarks.check_regression <fresh.json>
check-regression-all:
	$(BENCH_ENV) $(PY) -m benchmarks.check_regression --run-all \
		--fresh-dir bench_fresh

# Tier-1 verify and smoke benchmarks in one command each.
PY ?= python

.PHONY: test test-fast bench-smoke bench bench-baselines bench-shards \
	bench-hotpath

test:
	$(PY) -m pytest -x -q

# Tier-1 subset: no hypothesis search — property tests draw at most 2
# deterministic examples each (see tests/_hypo.py).
test-fast:
	REPRO_FAST_EXAMPLES=2 $(PY) -m pytest -x -q

# Fast perf record: mixed-contract bytecode block through one jitted executor.
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.engine_bench --workload mixed --fast

# Four-engine comparison grid (sequential/Block-STM/Bohm/LiTM on mixed
# blocks) + branch-free-ALU A/B -> BENCH_baselines.json.
bench-baselines:
	PYTHONPATH=src $(PY) -m benchmarks.engine_bench --workload baselines --fast

# Sharded MV backend grid (n_locs x n_shards x zipf_s, up to 10M locations)
# -> BENCH_shards.json.
bench-shards:
	PYTHONPATH=src $(PY) -m benchmarks.engine_bench --workload shards --fast

# Wave hot-loop phase timings: incremental backend.update vs full rebuild
# per wave (+ end-to-end tps both ways) on the shard grid
# -> BENCH_hotpath.json (uploaded as a CI artifact).
bench-hotpath:
	PYTHONPATH=src $(PY) -m benchmarks.hotpath_bench --fast

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast

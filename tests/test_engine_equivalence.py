"""Property tests: Block-STM wave engine ≡ sequential execution.

This is the paper's central safety theorem (Appendix A, Lemma 1/Theorem 1):
for any block and any scheduling, the committed state equals the state of
executing transactions sequentially in the preset order.  We drive the engine
across randomized workloads, window sizes and backends with hypothesis.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import workloads as W
from repro.core.engine import make_executor, run_block
from repro.core.vm import run_sequential

jax.config.update("jax_platform_name", "cpu")


def _check_p2p(n_accounts, n_txns, window, seed, backend="sorted",
               cfg_reads=4):
    spec = W.P2PSpec(n_accounts=n_accounts, cfg_reads=cfg_reads)
    params, storage = W.make_p2p_block(spec, n_txns, seed=seed)
    cfg = W.p2p_engine_config(spec, n_txns, window=window, backend=backend)
    res = run_block(W.p2p_program(spec), params, storage, cfg)
    assert bool(res.committed), "engine hit wave cap without committing"
    expected = run_sequential(W.p2p_program(spec), params, storage, n_txns)
    np.testing.assert_array_equal(np.asarray(res.snapshot), expected)
    return res


@settings(max_examples=25, deadline=None)
@given(
    n_accounts=st.sampled_from([2, 3, 10, 50]),
    n_txns=st.integers(4, 48),
    window=st.sampled_from([1, 2, 7, 32]),
    seed=st.integers(0, 2**16),
)
def test_p2p_equivalence(n_accounts, n_txns, window, seed):
    _check_p2p(n_accounts, n_txns, window, seed)


@settings(max_examples=10, deadline=None)
@given(
    n_slots=st.integers(2, 20),
    n_txns=st.integers(4, 40),
    window=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**16),
    repoint=st.floats(0.0, 1.0),
)
def test_indirect_equivalence(n_slots, n_txns, window, seed, repoint):
    """Dynamic read sets (pointer chasing): locations discovered mid-execution."""
    spec = W.IndirectSpec(n_slots=n_slots)
    params, storage = W.make_indirect_block(spec, n_txns, seed=seed,
                                            repoint_prob=repoint)
    cfg = W.indirect_engine_config(spec, n_txns, window=window)
    res = run_block(W.indirect_program(spec), params, storage, cfg)
    assert bool(res.committed)
    expected = run_sequential(W.indirect_program(spec), params, storage,
                              n_txns)
    np.testing.assert_array_equal(np.asarray(res.snapshot), expected)


@settings(max_examples=10, deadline=None)
@given(
    n_txns=st.integers(4, 40),
    window=st.sampled_from([1, 8, 32]),
    seed=st.integers(0, 2**16),
)
def test_admission_equivalence(n_txns, window, seed):
    """Hot shared counter (free-list head): worst-case conflict chain."""
    spec = W.AdmissionSpec(n_tenants=3, n_groups=8, total_pages=n_txns * 3,
                           quota_per_tenant=n_txns)
    params, storage = W.make_admission_block(spec, n_txns, seed=seed)
    cfg = W.admission_engine_config(spec, n_txns, window=window)
    res = run_block(W.admission_program(spec), params, storage, cfg)
    assert bool(res.committed)
    expected = run_sequential(W.admission_program(spec), params, storage,
                              n_txns)
    np.testing.assert_array_equal(np.asarray(res.snapshot), expected)


def test_dense_backend_equivalence():
    for seed in range(3):
        _check_p2p(10, 32, 8, seed, backend="dense")


def test_dense_pallas_backend():
    spec = W.P2PSpec(n_accounts=10)
    params, storage = W.make_p2p_block(spec, 24, seed=0)
    cfg = W.p2p_engine_config(spec, 24, window=8, backend="dense",
                              use_pallas=True)
    res = run_block(W.p2p_program(spec), params, storage, cfg)
    assert bool(res.committed)
    expected = run_sequential(W.p2p_program(spec), params, storage, 24)
    np.testing.assert_array_equal(np.asarray(res.snapshot), expected)


def test_determinism_across_windows():
    """Paper: every execution of the block yields the same outcome —
    regardless of the parallelism (window size / thread count)."""
    snaps = []
    for window in (1, 3, 8, 64):
        res = _check_p2p(5, 40, window, seed=7)
        snaps.append(np.asarray(res.snapshot))
    for s in snaps[1:]:
        np.testing.assert_array_equal(snaps[0], s)


def test_fully_sequential_workload_overhead():
    """2 accounts => every txn conflicts with the previous one (paper §4.1).
    The engine must degrade gracefully: ~1 commit per wave, bounded
    re-execution."""
    spec = W.P2PSpec(n_accounts=2)
    params, storage = W.make_p2p_block(spec, 48, seed=3)
    cfg = W.p2p_engine_config(spec, 48, window=8)
    res = run_block(W.p2p_program(spec), params, storage, cfg)
    assert bool(res.committed)
    # incarnations bounded: at most ~2 executions per txn + window slack
    assert int(res.execs) < 3 * 48, int(res.execs)


def test_low_contention_near_optimal():
    """Many accounts => most txns commit with exactly one incarnation."""
    spec = W.P2PSpec(n_accounts=2000)
    params, storage = W.make_p2p_block(spec, 128, seed=11)
    cfg = W.p2p_engine_config(spec, 128, window=128)
    res = run_block(W.p2p_program(spec), params, storage, cfg)
    assert bool(res.committed)
    assert int(res.execs) <= int(128 * 1.25), int(res.execs)
    assert int(res.waves) <= 6, int(res.waves)


def test_jit_executor_reuse():
    spec = W.P2PSpec(n_accounts=10)
    cfg = W.p2p_engine_config(spec, 32, window=8)
    run = make_executor(W.p2p_program(spec), cfg)
    for seed in range(3):
        params, storage = W.make_p2p_block(spec, 32, seed=seed)
        res = run(params, storage)
        expected = run_sequential(W.p2p_program(spec), params, storage, 32)
        np.testing.assert_array_equal(np.asarray(res.snapshot), expected)


def test_chain_of_blocks():
    """run_chain: each block's committed state feeds the next block."""
    from repro.core.engine import run_chain
    import jax

    spec = W.P2PSpec(n_accounts=20)
    n_txns, n_blocks = 32, 4
    cfg = W.p2p_engine_config(spec, n_txns, window=8)
    blocks = []
    for b in range(n_blocks):
        params, storage0 = W.make_p2p_block(spec, n_txns, seed=100 + b)
        blocks.append(params)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *blocks)

    final, results = jax.jit(
        lambda bp, st: run_chain(W.p2p_program(spec), bp, st, cfg)
    )(stacked, storage0)
    assert bool(np.asarray(results.committed).all())

    # sequential reference over the whole chain
    state = np.asarray(storage0)
    for b in range(n_blocks):
        state = run_sequential(W.p2p_program(spec), blocks[b], state, n_txns)
    np.testing.assert_array_equal(np.asarray(final), state)

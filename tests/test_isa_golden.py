"""Golden ISA tests: one table-driven case per opcode, hand-computed results.

Every case pins down the exact register file and storage state a tiny
program must produce, in BOTH harnesses of the executor protocol:

* the speculative JAX path (``execute_spec`` inside ``run_block``), under
  both dispatch modes (branch-free gather ALU and legacy ``lax.switch``);
* the plain-Python sequential path (``BytecodeVM._interp`` + ``OracleCtx``),
  whose final register file is checked against hand-computed values.

All programs share one static shape (L=12 ops, 8 regs, 8 locs, R=2, W=3,
P=3 args), so the jitted spec-path executor compiles exactly once per
dispatch mode for the whole table.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bytecode import BytecodeVM, isa
from repro.core import workloads as W
from repro.core.engine import make_executor
from repro.core.vm import OracleCtx

jax.config.update("jax_platform_name", "cpu")

L, NREGS, NLOCS, MAXR, MAXW, NARGS = 12, 8, 8, 2, 3, 3
IMIN, IMAX = -2**31, 2**31 - 1
AL = isa.ALWAYS

CFG = W.EngineConfig(n_txns=1, n_locs=NLOCS, max_reads=MAXR, max_writes=MAXW,
                     window=1)
_EXEC = {d: make_executor(BytecodeVM(NREGS, dispatch=d), CFG)
         for d in ("gather", "switch")}


def case(name, rows, *, regs, mem=None, args=(0, 0, 0), storage=None):
    """mem: {loc: expected value} over the initial storage."""
    return dict(name=name, rows=rows, regs=list(regs), mem=mem or {},
                args=list(args), storage=storage or [0] * NLOCS)


# Hand-computed expectations.  hash_mix literals (murmur3-style finalizer,
# see isa.hash_mix): hash(0,0)=0, hash(1,17)=-1985740003, hash(42,17)=262568258.
CASES = [
    case("load_imm",
         [[isa.LOAD_IMM, 1, 42, 0], [isa.LOAD_IMM, 2, -7, 0],
          [isa.WRITE, 0, 1, AL]],
         regs=[0, 42, -7, 0, 0, 0, 0, 0], mem={0: 42}),
    case("load_param_clamps",
         [[isa.LOAD_PARAM, 1, 0, 0], [isa.LOAD_PARAM, 2, 1, 0],
          [isa.LOAD_PARAM, 3, 99, 0],          # idx 99 clamps to args[-1]
          [isa.WRITE, 0, 3, AL]],
         args=(7, -3, 9),
         regs=[0, 7, -3, 9, 0, 0, 0, 0], mem={0: 9}),
    case("mov",
         [[isa.LOAD_IMM, 1, 5, 0], [isa.MOV, 2, 1, 0], [isa.WRITE, 0, 2, AL]],
         regs=[0, 5, 5, 0, 0, 0, 0, 0], mem={0: 5}),
    case("add_wraps_int32",
         [[isa.LOAD_IMM, 1, IMAX, 0], [isa.LOAD_IMM, 2, 1, 0],
          [isa.ADD, 3, 1, 2], [isa.WRITE, 0, 3, AL]],
         regs=[0, IMAX, 1, IMIN, 0, 0, 0, 0], mem={0: IMIN}),
    case("sub_wraps_int32",
         [[isa.LOAD_IMM, 1, IMIN, 0], [isa.LOAD_IMM, 2, 1, 0],
          [isa.SUB, 3, 1, 2], [isa.WRITE, 0, 3, AL]],
         regs=[0, IMIN, 1, IMAX, 0, 0, 0, 0], mem={0: IMAX}),
    case("mul_wraps_int32",
         [[isa.LOAD_IMM, 1, 65536, 0], [isa.MUL, 3, 1, 1],   # 2^32 -> 0
          [isa.LOAD_IMM, 2, -3, 0], [isa.MUL, 4, 1, 2],
          [isa.WRITE, 0, 4, AL]],
         regs=[0, 65536, -3, 0, -196608, 0, 0, 0], mem={0: -196608}),
    case("ge",
         [[isa.LOAD_IMM, 1, 3, 0], [isa.LOAD_IMM, 2, 3, 0],
          [isa.GE, 3, 1, 2], [isa.LOAD_IMM, 4, 2, 0], [isa.GE, 5, 4, 1],
          [isa.WRITE, 0, 3, AL]],
         regs=[0, 3, 3, 1, 2, 0, 0, 0], mem={0: 1}),
    case("le",
         [[isa.LOAD_IMM, 1, 3, 0], [isa.LOAD_IMM, 4, 2, 0],
          [isa.LE, 3, 4, 1], [isa.LE, 5, 1, 4], [isa.WRITE, 0, 3, AL]],
         regs=[0, 3, 0, 1, 2, 0, 0, 0], mem={0: 1}),
    case("and",
         [[isa.LOAD_IMM, 1, 5, 0], [isa.AND, 3, 1, 1], [isa.AND, 4, 1, 2],
          [isa.WRITE, 0, 3, AL]],
         regs=[0, 5, 0, 1, 0, 0, 0, 0], mem={0: 1}),
    case("select_both_arms",
         [[isa.LOAD_IMM, 1, 1, 0], [isa.LOAD_IMM, 2, 10, 0],
          [isa.LOAD_IMM, 3, 20, 0],
          [isa.SELECT, 1, 2, 3],                # r1 != 0 -> picks r2
          [isa.SELECT, 4, 2, 3],                # r4 == 0 -> picks r3
          [isa.LOAD_IMM, 5, 1, 0],
          [isa.WRITE, 0, 1, AL], [isa.WRITE, 5, 4, AL]],
         regs=[0, 10, 10, 20, 20, 1, 0, 0], mem={0: 10, 1: 20}),
    case("read",
         [[isa.LOAD_IMM, 1, 1, 0], [isa.READ, 2, 1, AL],
          [isa.LOAD_IMM, 3, 2, 0], [isa.READ, 4, 3, AL],
          [isa.ADD, 5, 2, 4], [isa.WRITE, 0, 5, AL]],
         storage=[0, 55, 66, 0, 0, 0, 0, 0],
         regs=[0, 1, 55, 2, 66, 121, 0, 0], mem={0: 121}),
    case("read_disabled_yields_zero",
         [[isa.LOAD_IMM, 1, 1, 0],
          [isa.READ, 2, 1, 6],                  # enable mask r6 == 0 -> off
          [isa.WRITE, 0, 2, AL]],
         storage=[-9, 0, 0, 0, 0, 0, 0, 0],
         regs=[0, 1, 0, 0, 0, 0, 0, 0], mem={0: 0}),
    case("write_disabled_leaves_storage",
         [[isa.LOAD_IMM, 1, 7, 0],
          [isa.WRITE, 0, 1, 6],                 # enable mask r6 == 0 -> off
          [isa.LOAD_IMM, 2, 1, 0], [isa.LOAD_IMM, 3, 8, 0],
          [isa.WRITE, 2, 3, AL]],
         storage=[-9, 0, 0, 0, 0, 0, 0, 0],
         regs=[0, 7, 1, 8, 0, 0, 0, 0], mem={0: -9, 1: 8}),
    case("halt_kills_tail",
         [[isa.LOAD_IMM, 1, 3, 0], [isa.WRITE, 0, 1, AL],
          [isa.HALT, 0, 0, 0],
          [isa.LOAD_IMM, 2, 9, 0], [isa.WRITE, 0, 2, AL]],
         regs=[0, 3, 0, 0, 0, 0, 0, 0], mem={0: 3}),
    case("undefined_opcode_traps_to_halt",
         [[isa.LOAD_IMM, 1, 3, 0], [isa.LOAD_IMM, 2, 9, 0],
          [isa.WRITE, 0, 1, AL],
          [99, 0, 0, 0],                        # not an opcode -> HALT trap
          [isa.WRITE, 0, 2, AL]],
         regs=[0, 3, 9, 0, 0, 0, 0, 0], mem={0: 3}),
    case("div_floors",
         [[isa.LOAD_IMM, 1, 7, 0], [isa.LOAD_IMM, 2, 2, 0],
          [isa.DIV, 3, 1, 2],                   # 7 // 2 = 3
          [isa.LOAD_IMM, 4, -7, 0], [isa.DIV, 5, 4, 2],   # -7 // 2 = -4
          [isa.LOAD_IMM, 7, 1, 0],
          [isa.WRITE, 0, 3, AL], [isa.WRITE, 7, 5, AL]],
         regs=[0, 7, 2, 3, -7, -4, 0, 1], mem={0: 3, 1: -4}),
    case("div_by_zero_and_intmin",
         [[isa.LOAD_IMM, 1, 5, 0],
          [isa.DIV, 2, 1, 0],                   # r0 == 0: 5 / 0 -> 0
          [isa.LOAD_IMM, 3, IMIN, 0], [isa.LOAD_IMM, 4, -1, 0],
          [isa.DIV, 5, 3, 4],                   # IMIN / -1 wraps to IMIN
          [isa.WRITE, 0, 5, AL]],
         regs=[0, 5, 0, IMIN, -1, IMIN, 0, 0], mem={0: IMIN}),
    case("mod_floor_sign_of_divisor",
         [[isa.LOAD_IMM, 1, 7, 0], [isa.LOAD_IMM, 2, 3, 0],
          [isa.MOD, 3, 1, 2],                   # 7 mod 3 = 1
          [isa.LOAD_IMM, 4, -7, 0], [isa.MOD, 5, 4, 2],   # -7 mod 3 = 2
          [isa.LOAD_IMM, 6, -3, 0], [isa.MOD, 7, 1, 6],   # 7 mod -3 = -2
          [isa.WRITE, 0, 5, AL]],
         regs=[0, 7, 3, 1, -7, 2, -3, -2], mem={0: 2}),
    case("mod_by_zero",
         [[isa.LOAD_IMM, 1, 7, 0], [isa.MOD, 2, 1, 0],
          [isa.WRITE, 0, 2, AL]],
         storage=[-9, 0, 0, 0, 0, 0, 0, 0],
         regs=[0, 7, 0, 0, 0, 0, 0, 0], mem={0: 0}),
    case("hash_mix_literals",
         [[isa.LOAD_IMM, 1, 42, 0], [isa.LOAD_IMM, 2, 17, 0],
          [isa.HASH, 3, 1, 2],                  # hash(42, 17)
          [isa.HASH, 4, 0, 0],                  # hash(0, 0) = 0
          [isa.LOAD_IMM, 5, 1, 0], [isa.HASH, 6, 5, 2],   # hash(1, 17)
          [isa.WRITE, 0, 3, AL]],
         regs=[0, 42, 17, 262568258, 0, 1, -1985740003, 0],
         mem={0: 262568258}),
]


def _code(rows):
    code = np.zeros((L, isa.N_FIELDS), np.int32)   # op 0 == HALT padding
    code[:len(rows)] = np.asarray(rows, np.int32)
    return code


def _expected_storage(c):
    out = np.asarray(c["storage"], np.int32).copy()
    for loc, val in c["mem"].items():
        out[loc] = val
    return out


@pytest.mark.parametrize("dispatch", ["gather", "switch"])
@pytest.mark.parametrize("c", CASES, ids=[c["name"] for c in CASES])
def test_golden_spec_path(c, dispatch):
    """Speculative JAX path: committed snapshot matches the hand computation.

    Results are routed through WRITEs, so the register golden values are
    exercised on this path wherever they are externally observable.
    """
    params = {"code": jnp.asarray(_code(c["rows"])[None]),
              "args": jnp.asarray(np.asarray(c["args"], np.int32)[None])}
    storage = jnp.asarray(np.asarray(c["storage"], np.int32))
    res = _EXEC[dispatch](params, storage)
    assert bool(res.committed), c["name"]
    np.testing.assert_array_equal(np.asarray(res.snapshot),
                                  _expected_storage(c), err_msg=c["name"])


@pytest.mark.parametrize("c", CASES, ids=[c["name"] for c in CASES])
def test_golden_oracle_path(c):
    """Sequential Python path: full register file + storage, hand-computed."""
    vm = BytecodeVM(NREGS)
    state: dict = {}
    storage = np.asarray(c["storage"], np.int32)
    ctx = OracleCtx(state, storage)
    regs = vm._interp({"code": _code(c["rows"]),
                       "args": np.asarray(c["args"], np.int32)}, ctx)
    ctx.commit()
    assert [int(r) for r in regs] == c["regs"], c["name"]
    out = storage.copy()
    for loc, val in state.items():
        out[loc] = val
    np.testing.assert_array_equal(out, _expected_storage(c),
                                  err_msg=c["name"])


def test_disassemble_new_opcodes():
    rows = [[isa.DIV, 1, 2, 3], [isa.MOD, 1, 2, 3], [isa.HASH, 1, 2, 3],
            [isa.HALT, 0, 0, 0]]
    text = isa.disassemble(np.asarray(rows, np.int32))
    assert "DIV" in text and "MOD" in text and "HASH" in text

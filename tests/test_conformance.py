"""Differential conformance: all four engines, one committed snapshot.

The bytecode analogue of ``test_engine_equivalence.py``, run through the
unified executor protocol (``repro.core.executor.run_engine``): sequential,
Block-STM, Bohm (perfect write sets), and LiTM must commit byte-identical
snapshots on random heterogeneous ``make_mixed_block`` workloads across
seeds, block sizes, contract mixes, and conflict rates — the property that
makes the paper's comparison grid (§4.1) meaningful on our richest workload.

Also here: the interpreter-dispatch A/B property (branch-free gather ALU ≡
legacy ``lax.switch``) and the compile-once property extended to the
baselines (the jit cache of the Bohm/LiTM executors does not grow across
contract mixes).
"""
import jax
import numpy as np
from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro.bytecode import BytecodeVM
from repro.bytecode import compile as BC
from repro.core import baselines as B
from repro.core import workloads as W
from repro.core.engine import run_block
from repro.core.executor import ENGINES, run_engine

jax.config.update("jax_platform_name", "cpu")

# Conflict rate is set by the size of the shared-location universes: tiny
# account/slot/tenant pools make nearly every transaction conflict, large
# pools almost none (paper Fig. 4's contention axis).
_CONTENTION = {
    "high": W.MixedSpec(
        p2p=W.P2PSpec(n_accounts=3),
        indirect=W.IndirectSpec(n_slots=3),
        admission=W.AdmissionSpec(n_tenants=2, n_groups=2, total_pages=64,
                                  quota_per_tenant=48)),
    "low": W.MixedSpec(
        p2p=W.P2PSpec(n_accounts=64),
        indirect=W.IndirectSpec(n_slots=48),
        admission=W.AdmissionSpec(n_tenants=12, n_groups=16,
                                  total_pages=10**6,
                                  quota_per_tenant=10**5)),
}


def _mixed(n_txns, seed, ratios, contention, window=8):
    import dataclasses
    spec = dataclasses.replace(_CONTENTION[contention], ratios=ratios)
    return W.make_mixed_block(spec, n_txns, seed=seed, window=window)


def _assert_all_engines_agree(vm, params, storage, cfg, msg=""):
    ref, _, _ = run_engine("sequential", vm, params, storage, cfg)
    # one oracle pre-pass shared by the bohm run (as the paper shares it)
    pws = B.perfect_write_sets(vm, params, storage, cfg)
    for name in ("blockstm", "bohm", "litm"):
        snap, committed, _ = run_engine(name, vm, params, storage, cfg,
                                        perfect_write_locs=pws)
        assert bool(committed), f"{name} failed to commit {msg}"
        np.testing.assert_array_equal(
            np.asarray(snap), np.asarray(ref),
            err_msg=f"{name} diverged from sequential {msg}")


@settings(max_examples=10, deadline=None)
@given(n_txns=st.sampled_from([6, 14, 26]), seed=st.integers(0, 2**16),
       ratios=st.sampled_from([(1, 1, 1), (4, 1, 1), (1, 4, 1), (1, 1, 4)]),
       contention=st.sampled_from(["high", "low"]))
def test_four_engines_identical_snapshots(n_txns, seed, ratios, contention):
    """sequential == blockstm == bohm == litm on random mixed blocks."""
    vm, params, storage, cfg = _mixed(n_txns, seed, ratios, contention)
    _assert_all_engines_agree(
        vm, params, storage, cfg,
        msg=f"(n={n_txns} seed={seed} ratios={ratios} {contention})")


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16), n_txns=st.sampled_from([8, 20]))
def test_dispatch_modes_agree(seed, n_txns):
    """Branch-free gather ALU ≡ legacy lax.switch dispatch, engine-level."""
    vm, params, storage, cfg = _mixed(n_txns, seed, (1, 1, 1), "high")
    assert vm.dispatch == "gather"
    res_g = run_block(vm, params, storage, cfg)
    res_s = run_block(BytecodeVM(vm.n_regs, dispatch="switch"),
                      params, storage, cfg)
    assert bool(res_g.committed) and bool(res_s.committed)
    np.testing.assert_array_equal(np.asarray(res_g.snapshot),
                                  np.asarray(res_s.snapshot))


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16), n_txns=st.sampled_from([6, 18]))
def test_hashed_admission_conformance(seed, n_txns):
    """HASH/MOD key derivation in bytecode: all four engines agree.

    ``compile_admission_hashed`` has no DSL counterpart (the derivation used
    to require host-side precomputation), so the sequential interpretation of
    the SAME bytecode is the ground truth.
    """
    spec = W.AdmissionSpec(n_tenants=3, n_groups=5, total_pages=64,
                           quota_per_tenant=40)
    prog = BC.compile_admission_hashed(spec)
    params, storage = W.make_admission_block(spec, n_txns, seed=seed)
    args = BC.pack_args({k: np.asarray(v) for k, v in params.items()},
                        BC.ADMISSION_ARGS, prog.n_params)
    bparams = BC.homogeneous_block_params(prog, args)
    vm, cfg = BC.vm_and_config([prog], n_txns, spec.n_locs, window=4)
    _assert_all_engines_agree(vm, bparams, storage, cfg,
                              msg=f"(hashed admission seed={seed})")


def test_engines_registry_complete():
    assert ENGINES == ("sequential", "blockstm", "bohm", "litm")
    import pytest
    with pytest.raises(ValueError):
        run_engine("calvin", lambda p, ctx: None, {}, np.zeros(1),
                   W.EngineConfig(n_txns=1, n_locs=1, max_reads=1,
                                  max_writes=1))


def test_baseline_executors_zero_recompile():
    """Compile-once extends to the baselines: re-running Bohm/LiTM on a
    different p2p/indirect/admission ratio must NOT grow the jit cache."""
    n = 24
    mixes = [(1, 1, 1), (5, 1, 1), (1, 5, 1), (1, 1, 5), (0, 1, 1)]
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(ratios=mixes[0]), n, seed=0)
    bohm = B.make_baseline_executor("bohm", vm, cfg)
    litm = B.make_baseline_executor("litm", vm, cfg)
    for i, ratios in enumerate(mixes):
        _, params_i, storage_i, cfg_i = W.make_mixed_block(
            W.MixedSpec(ratios=ratios), n, seed=i)
        assert cfg_i == cfg  # same static config => same compiled program
        ref, _, _ = run_engine("sequential", vm, params_i, storage_i, cfg)
        pws = B.perfect_write_sets(vm, params_i, storage_i, cfg)
        rb = bohm(params_i, storage_i, pws)
        rl = litm(params_i, storage_i)
        assert bool(rb.committed) and bool(rl.committed)
        np.testing.assert_array_equal(np.asarray(rb.snapshot),
                                      np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(rl.snapshot),
                                      np.asarray(ref))
    assert bohm._cache_size() == 1, \
        f"bohm recompiled: cache has {bohm._cache_size()} entries"
    assert litm._cache_size() == 1, \
        f"litm recompiled: cache has {litm._cache_size()} entries"

"""Observability property suite (``repro.obs``).

* Zero-cost gating — ``trace_level=0`` (the default) carries ``trace=None``
  through the engine: byte-identical snapshots and identical stats to every
  traced level, on every MV backend and on the dist engine across 1/2/8
  virtual devices.
* Counter invariants — per-wave buffers decompose the engine's BlockResult
  scalars exactly: ``wave_size == execs + dep_aborts`` per wave, the
  per-wave sums equal the block totals, the frontier is monotone and
  reaches ``n_txns``, and every level-2 abort edge respects the preset
  order (``blocker < blocked``).
* Compile-once — a traced executor still serves every contract mix with
  zero recompiles.
* Export — wave-trace JSON round-trips bit-exactly; the Chrome-trace
  export carries one complete event per wave; the report CLI renders.
* Profiling — ``obs.profile.profile_block`` writes a perfetto dump.

Dist coverage follows ``tests/test_dist.py``'s convention: the suite skips
mesh tests below 8 devices and re-runs itself in a subprocess with
``--xla_force_host_platform_device_count=8``.
"""
import dataclasses
import glob
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
from _hypo import given, settings, st

from repro import obs
from repro.core import workloads as W
from repro.core.engine import make_executor, run_block
from repro.core.types import EngineConfig
from repro.launch.mesh import make_mesh
from repro.obs import export as X
from repro.obs import report as R

jax.config.update("jax_platform_name", "cpu")

REQUIRED = 8
_FLAG = f"--xla_force_host_platform_device_count={REQUIRED}"

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < REQUIRED,
    reason=f"needs {REQUIRED} virtual devices (XLA_FLAGS={_FLAG}); "
    f"covered via the subprocess runner")

STATS = ("committed", "waves", "execs", "dep_aborts", "val_aborts",
         "wrote_new")


def _stats(res):
    return tuple(int(getattr(res, f)) for f in STATS)


def _block(n_txns=48, seed=3, backend="sorted", trace_level=0, **kw):
    shards = dict(n_shards=8) if backend == "sharded" else {}
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), n_txns, seed=seed, backend=backend, **shards, **kw)
    return vm, params, storage, dataclasses.replace(cfg,
                                                    trace_level=trace_level)


# ---------------------------------------------------------------------------
# Subprocess runner: tier-1 dist coverage without process-wide XLA flags
# ---------------------------------------------------------------------------

def test_obs_suite_under_virtual_mesh():
    if len(jax.devices()) >= REQUIRED:
        pytest.skip("already on a virtual mesh; suite runs directly")
    env = dict(os.environ, XLA_FLAGS=_FLAG, JAX_PLATFORMS="cpu")
    env.setdefault("REPRO_FAST_EXAMPLES", "2")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=3000)
    assert r.returncode == 0, \
        f"obs suite failed under {_FLAG}:\n{r.stdout[-4000:]}\n" \
        f"{r.stderr[-2000:]}"


# ---------------------------------------------------------------------------
# Gating: level 0 is the untraced engine; invalid levels refuse
# ---------------------------------------------------------------------------

def test_config_rejects_unknown_trace_level():
    with pytest.raises(ValueError, match="trace_level"):
        EngineConfig(n_txns=8, n_locs=64, max_reads=4, max_writes=4,
                     trace_level=3)


def test_level0_trace_is_empty_pytree():
    _, _, _, cfg = _block(trace_level=0)
    assert obs.init_trace(cfg) is None
    # and an enabled config allocates buffers sized by the wave cap
    _, _, _, c2 = _block(trace_level=2)
    tr = obs.init_trace(c2)
    assert tr.frontier.shape == (c2.waves_cap(),)
    assert tr.blocked_ids.shape == (c2.waves_cap(), c2.window)


@pytest.mark.parametrize("backend", ["dense", "sorted", "sharded"])
def test_level0_matches_traced_levels(backend):
    vm, params, storage, cfg = _block(backend=backend, trace_level=0)
    ref = run_block(vm, params, storage, cfg)
    assert ref.trace is None
    for lvl in (1, 2):
        res = run_block(vm, params, storage,
                        dataclasses.replace(cfg, trace_level=lvl))
        np.testing.assert_array_equal(np.asarray(res.snapshot),
                                      np.asarray(ref.snapshot),
                                      err_msg=f"{backend} level {lvl}")
        assert _stats(res) == _stats(ref), (backend, lvl)
        assert (res.trace.blocked_ids is None) == (lvl < 2)


def test_traced_executor_zero_recompiles_across_mixes():
    vm, params, storage, cfg = _block(trace_level=2)
    run = make_executor(vm, cfg)
    for i, ratios in enumerate([(1, 1, 1), (8, 1, 1), (1, 1, 8)]):
        _, params, storage, _ = W.make_mixed_block(
            W.MixedSpec(ratios=ratios), cfg.n_txns, seed=20 + i)
        res = run(params, storage)
        assert bool(res.committed)
    assert run._cache_size() == 1, run._cache_size()


# ---------------------------------------------------------------------------
# Counter invariants: the buffers decompose BlockStats exactly
# ---------------------------------------------------------------------------

def _check_invariants(res, n_txns):
    t, w = res.trace, int(res.waves)
    ws, ex, da = (np.asarray(t.wave_size), np.asarray(t.execs),
                  np.asarray(t.dep_aborts))
    np.testing.assert_array_equal(ws[:w], ex[:w] + da[:w])
    assert ex[:w].sum() == int(res.execs)
    assert da[:w].sum() == int(res.dep_aborts)
    assert np.asarray(t.val_aborts)[:w].sum() == int(res.val_aborts)
    fr = np.asarray(t.frontier)[:w]
    assert (np.diff(fr) >= 0).all(), "frontier must be monotone"
    assert fr[-1] == n_txns and bool(res.committed)
    # single device: every live lane executes here
    np.testing.assert_array_equal(np.asarray(t.exec_lanes)[:w], ws[:w])
    # unreached waves stay at init values
    assert (ws[w:] == 0).all() and (fr[w:] == 0).all()
    # reads issued only on waves that executed something
    er = np.asarray(t.exec_reads)[:w]
    assert ((er > 0) == (ws[:w] > 0)).all() or (er[ws[:w] > 0] >= 0).all()
    if t.blocked_ids is not None:
        bi, bl = np.asarray(t.blocked_ids), np.asarray(t.blockers)
        live = bi != obs.NO_TXN
        # one edge per dep-aborted lane, blocker strictly earlier in the
        # preset order, both ends valid txn ids
        np.testing.assert_array_equal(live[:w].sum(axis=1), da[:w])
        assert (bl[live] < bi[live]).all()
        assert (bl[live] >= 0).all() and (bi[live] < n_txns).all()


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**16),
       backend=st.sampled_from(["dense", "sorted", "sharded"]))
def test_trace_counter_invariants(seed, backend):
    vm, params, storage, cfg = _block(seed=seed, backend=backend,
                                      trace_level=2)
    res = run_block(vm, params, storage, cfg)
    _check_invariants(res, cfg.n_txns)


def test_trace_invariants_across_engine_variants():
    """The hooks must stay coherent under every maintenance/validation
    regime (the rebuild path has no delta: dirty_regions pins to -1)."""
    vm, params, storage, cfg = _block(backend="sharded", trace_level=2)
    for variant in (dict(),
                    dict(mv_update="rebuild", dirty_validation=False),
                    dict(dirty_validation=False),
                    dict(validation_window=16),
                    dict(dirty_validation_cap=2)):
        c = dataclasses.replace(cfg, **variant)
        res = run_block(vm, params, storage, c)
        _check_invariants(res, cfg.n_txns)
        w = int(res.waves)
        dirty = np.asarray(res.trace.dirty_regions)[:w]
        if variant.get("mv_update") == "rebuild":
            assert (dirty == -1).all()
        else:
            assert (dirty >= 0).all()
        if not c.dirty_validation:
            assert (np.asarray(res.trace.skip_hits)[:w] == 0).all()


def test_degenerate_dirty_cap_is_not_a_fallback():
    """Regression: when ``dirty_cap() >= n_txns`` the cap cannot narrow the
    work, so ``_validate_dirty`` takes its full-width early return — that is
    the cap DISABLED, not the cap overflowing.  It used to stamp
    ``skip_fallback=True`` on every wave, making small blocks report a 100%
    cap-fallback rate; it must report False, with skip-hit/miss lane
    accounting intact."""
    vm, params, storage, cfg = _block(backend="sharded", trace_level=1)
    assert cfg.dirty_cap() >= cfg.n_txns, "fixture must hit the degenerate cap"
    res = run_block(vm, params, storage, cfg)
    w = int(res.waves)
    t = res.trace
    assert not np.asarray(t.skip_fallback)[:w].any(), \
        "degenerate cap reported as fallback"
    # lane accounting unaffected: hits+misses still cover the skip decisions
    hits = np.asarray(t.skip_hits)[:w]
    misses = np.asarray(t.skip_misses)[:w]
    assert (hits + misses > 0).any()
    assert (hits >= 0).all() and (misses >= 0).all()
    # a cap that genuinely CAN overflow still reports fallback when it does
    c2 = dataclasses.replace(cfg, dirty_validation_cap=2)
    assert c2.dirty_cap() < c2.n_txns
    r2 = run_block(vm, params, storage, c2)
    assert np.asarray(r2.trace.skip_fallback)[:int(r2.waves)].any(), \
        "cap-2 run never overflowed — fixture too tame for the contrast leg"


# ---------------------------------------------------------------------------
# Dist engine: replicated fields identical, per-device fields sum exactly
# ---------------------------------------------------------------------------

REPLICATED_FIELDS = ("frontier", "wave_size", "execs", "dep_aborts",
                     "val_aborts", "exec_reads", "val_reads", "skip_hits",
                     "skip_misses", "skip_fallback", "blocked_ids",
                     "blockers")


@needs_mesh
def test_dist_trace_matches_single_device():
    vm, params, storage, cfg = _block(n_txns=64, backend="sharded",
                                      trace_level=2, n_locs=50_000,
                                      zipf_s=1.1)
    ref = run_block(vm, params, storage, cfg)
    for d in (1, 2, 8):
        dcfg = dataclasses.replace(cfg, dist=True,
                                   mesh=make_mesh("regions", (d,)))
        res = run_block(vm, params, storage, dcfg)
        np.testing.assert_array_equal(np.asarray(res.snapshot),
                                      np.asarray(ref.snapshot))
        assert _stats(res) == _stats(ref)
        for f in REPLICATED_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(res.trace, f)),
                np.asarray(getattr(ref.trace, f)), err_msg=f"D={d} {f}")
        # per-device views: (D, cap), summing to the single-device counts
        for f in ("mv_entries", "dirty_regions", "exec_lanes"):
            a = np.asarray(getattr(res.trace, f))
            assert a.shape == (d, cfg.waves_cap()), (f, a.shape)
            np.testing.assert_array_equal(
                a.sum(axis=0), np.asarray(getattr(ref.trace, f)),
                err_msg=f"D={d} {f}")
        # the Chrome-trace export of the DIST trace still sums to the
        # block's stats (the acceptance property, mesh edition)
        ct = X.to_chrome_trace(X.trace_to_dict(res.trace, res.waves))
        spans = [e for e in ct["traceEvents"] if e["ph"] == "X"]
        for field in ("execs", "dep_aborts", "val_aborts"):
            assert sum(e["args"][field] for e in spans) == int(
                getattr(res, field)), (d, field)


@needs_mesh
def test_dist_level0_carries_no_trace():
    vm, params, storage, cfg = _block(n_txns=32, backend="sharded",
                                      trace_level=0)
    dcfg = dataclasses.replace(cfg, dist=True,
                               mesh=make_mesh("regions", (8,)))
    res = run_block(vm, params, storage, dcfg)
    assert res.trace is None and bool(res.committed)


# ---------------------------------------------------------------------------
# Export round-trip, Chrome trace, report, profiler dump
# ---------------------------------------------------------------------------

def _traced_result():
    vm, params, storage, cfg = _block(trace_level=2)
    return run_block(vm, params, storage, cfg), cfg


def test_wave_trace_roundtrip(tmp_path):
    res, cfg = _traced_result()
    path = str(tmp_path / "WAVE_TRACE.json")
    X.write_wave_trace(path, res.trace, res.waves, meta={"n_txns": 48})
    d = X.load_wave_trace(path)
    assert d["waves"] == int(res.waves) and d["meta"]["n_txns"] == 48
    w = int(res.waves)
    for f in X.COUNTER_FIELDS:
        np.testing.assert_array_equal(
            d[f], np.asarray(getattr(res.trace, f))[:w].astype(int),
            err_msg=f)
    for f in X.DEVICE_FIELDS:
        np.testing.assert_array_equal(
            d[f][0], np.asarray(getattr(res.trace, f))[:w].astype(int),
            err_msg=f)
    # level-2 edges come back as exactly the live (blocked, blocker) pairs
    bi = np.asarray(res.trace.blocked_ids)[:w]
    bl = np.asarray(res.trace.blockers)[:w]
    for wv, pairs in enumerate(d["abort_edges"]):
        expect = [[int(b), int(k)] for b, k in zip(bi[wv], bl[wv])
                  if b != obs.NO_TXN]
        assert pairs == expect, wv


def test_wave_trace_schema_handshake(tmp_path):
    path = str(tmp_path / "bad.json")
    with open(path, "w") as f:
        f.write('{"schema": "something-else/v9", "waves": 0}')
    with pytest.raises(ValueError, match="schema"):
        X.load_wave_trace(path)


def test_chrome_trace_export(tmp_path):
    res, cfg = _traced_result()
    d = X.trace_to_dict(res.trace, res.waves)
    ct = X.write_chrome_trace(str(tmp_path / "ct.json"), d)
    spans = [e for e in ct["traceEvents"] if e["ph"] == "X"]
    assert len(spans) == int(res.waves)
    # the exported per-wave counters sum exactly to the block's stats
    for field in ("execs", "dep_aborts", "val_aborts"):
        assert sum(e["args"][field] for e in spans) == int(
            getattr(res, field)), field
    # virtual timebase: span width == the wave's attempted-lane count
    ws = np.asarray(res.trace.wave_size)
    for i, e in enumerate(spans):
        assert e["dur"] == max(int(ws[i]), 1)
        assert e["args"]["execs"] == int(np.asarray(res.trace.execs)[i])
    # wall-clock timebase when per-phase timings are supplied
    pt = [{"execute": 1e-3, "index": 5e-4, "validate": 2.5e-4}
          for _ in range(int(res.waves))]
    ct2 = X.to_chrome_trace(d, phase_times=pt)
    phase_spans = [e for e in ct2["traceEvents"]
                   if e["ph"] == "X" and e.get("tid") == 1]
    assert len(phase_spans) == 3 * int(res.waves)
    assert ct2["otherData"]["timebase"] == "wall_clock"


def test_report_renders(tmp_path):
    res, cfg = _traced_result()
    path = str(tmp_path / "WAVE_TRACE.json")
    X.write_wave_trace(path, res.trace, res.waves)
    out = R.render(X.load_wave_trace(path), max_rows=6, chains=3)
    assert f"frontier={cfg.n_txns}" in out
    assert "top blockers" in out or "no dep-aborts" in out


def test_profile_block_writes_perfetto_dump(tmp_path):
    logdir = str(tmp_path / "prof")
    with obs.profile.profile_block(logdir):
        vm, params, storage, cfg = _block(n_txns=16)
        with obs.profile.annotate("block[0]"):
            res = run_block(vm, params, storage, cfg)
            res.snapshot.block_until_ready()
    dumps = glob.glob(os.path.join(logdir, "**", "*.trace.json.gz"),
                      recursive=True)
    assert dumps, f"no perfetto dump under {logdir}"

"""Bytecode VM: compiled workloads ≡ Python-DSL counterparts, mixed blocks ≡
sequential execution, and the compile-once serving property (zero re-jits
across contract mixes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.bytecode import BytecodeVM, isa
from repro.bytecode import compile as BC
from repro.bytecode.assembler import Assembler
from repro.core import workloads as W
from repro.core.engine import make_executor, run_block
from repro.core.vm import OracleCtx, run_sequential, unstack_params

jax.config.update("jax_platform_name", "cpu")


def _bytecode_block(prog, order, dsl_params):
    args = BC.pack_args({k: np.asarray(v) for k, v in dsl_params.items()},
                        order, n_slots=prog.n_params)
    return BC.homogeneous_block_params(prog, args)


def _steps_sequential(program, params_list, storage):
    """Per-txn state trajectory under the sequential oracle."""
    state: dict = {}
    storage = np.asarray(storage)
    out = []
    for p in params_list:
        ctx = OracleCtx(state, storage)
        program(p, ctx)
        ctx.commit()
        out.append(dict(state))
    return out


def _families(n_accounts=10, n_slots=8):
    p2p = W.P2PSpec(n_accounts=n_accounts)
    ind = W.IndirectSpec(n_slots=n_slots)
    adm = W.AdmissionSpec(n_tenants=3, n_groups=8, total_pages=96,
                          quota_per_tenant=64)
    return [
        ("p2p", p2p, W.p2p_program(p2p), BC.compile_p2p(p2p), BC.P2P_ARGS,
         lambda n, s: W.make_p2p_block(p2p, n, seed=s)),
        ("indirect", ind, W.indirect_program(ind), BC.compile_indirect(ind),
         BC.INDIRECT_ARGS, lambda n, s: W.make_indirect_block(ind, n, seed=s)),
        ("admission", adm, W.admission_program(adm), BC.compile_admission(adm),
         BC.ADMISSION_ARGS, lambda n, s: W.make_admission_block(adm, n, seed=s)),
    ]


@settings(max_examples=8, deadline=None)
@given(n_txns=st.integers(4, 32), seed=st.integers(0, 2**16),
       fam_idx=st.sampled_from([0, 1, 2]))
def test_compiled_matches_dsl_txn_for_txn(n_txns, seed, fam_idx):
    """Sequential oracle: the bytecode program produces the SAME state as its
    Python-DSL counterpart after EVERY transaction, not just at block end."""
    name, spec, dsl_prog, prog, order, make = _families()[fam_idx]
    params, storage = make(n_txns, seed)
    bparams = _bytecode_block(prog, order, params)
    vm = BytecodeVM(n_regs=prog.n_regs)
    dsl_steps = _steps_sequential(dsl_prog, unstack_params(params, n_txns),
                                  storage)
    bc_steps = _steps_sequential(vm, unstack_params(bparams, n_txns), storage)
    for i, (d, b) in enumerate(zip(dsl_steps, bc_steps)):
        assert d == b, f"{name}: state diverged after txn {i}: {d} != {b}"


@settings(max_examples=6, deadline=None)
@given(n_txns=st.integers(4, 32), seed=st.integers(0, 2**16),
       window=st.sampled_from([1, 4, 16]),
       fam_idx=st.sampled_from([0, 1, 2]))
def test_compiled_engine_matches_dsl_engine(n_txns, seed, window, fam_idx):
    """Wave engine: bytecode block snapshot == DSL block snapshot == seq."""
    name, spec, dsl_prog, prog, order, make = _families()[fam_idx]
    params, storage = make(n_txns, seed)
    bparams = _bytecode_block(prog, order, params)
    vm, cfg = BC.vm_and_config([prog], n_txns, spec.n_locs, window=window)
    # exact op counts never exceed the DSL spec's (possibly padded) slot bounds
    assert cfg.max_reads <= spec.max_reads, name
    assert cfg.max_writes <= spec.max_writes, name
    res_bc = run_block(vm, bparams, storage, cfg)
    assert bool(res_bc.committed), name
    res_dsl = run_block(dsl_prog, params, storage, cfg)
    exp = run_sequential(dsl_prog, params, storage, n_txns)
    np.testing.assert_array_equal(np.asarray(res_bc.snapshot), exp)
    np.testing.assert_array_equal(np.asarray(res_dsl.snapshot),
                                  np.asarray(res_bc.snapshot))


@settings(max_examples=8, deadline=None)
@given(n_txns=st.integers(6, 40), seed=st.integers(0, 2**16),
       window=st.sampled_from([1, 8, 32]),
       backend=st.sampled_from(["sorted", "dense"]),
       ratios=st.sampled_from([(1, 1, 1), (4, 1, 1), (1, 1, 6), (0.2, 1, 0.2)]))
def test_mixed_block_equivalence(n_txns, seed, window, backend, ratios):
    """Heterogeneous blocks (the case Dickerson/Anjana-style access-spec STMs
    cannot express): engine snapshot == sequential OracleCtx ground truth."""
    spec = W.MixedSpec(p2p=W.P2PSpec(n_accounts=6),
                       indirect=W.IndirectSpec(n_slots=5),
                       admission=W.AdmissionSpec(n_tenants=2, n_groups=4,
                                                 total_pages=64,
                                                 quota_per_tenant=48),
                       ratios=ratios)
    vm, params, storage, cfg = W.make_mixed_block(spec, n_txns, seed=seed,
                                                  window=window,
                                                  backend=backend)
    res = run_block(vm, params, storage, cfg)
    assert bool(res.committed), "engine hit wave cap without committing"
    exp = run_sequential(vm, params, storage, n_txns)
    np.testing.assert_array_equal(np.asarray(res.snapshot), exp)


def test_mixed_zero_recompiles():
    """ONE jitted executor serves every contract mix: the jit cache holds a
    single entry after arbitrarily many different mixes (the compile-once
    serving path)."""
    n = 32
    mixes = [(1, 1, 1), (10, 1, 1), (1, 10, 1), (1, 1, 10), (0, 1, 1)]
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(ratios=mixes[0]), n, seed=0)
    run = make_executor(vm, cfg)
    for i, ratios in enumerate(mixes):
        vm_i, params_i, storage_i, cfg_i = W.make_mixed_block(
            W.MixedSpec(ratios=ratios), n, seed=i)
        assert cfg_i == cfg  # same static config => same compiled program
        res = run(params_i, storage_i)
        assert bool(res.committed)
        exp = run_sequential(vm, params_i, storage_i, n)
        np.testing.assert_array_equal(np.asarray(res.snapshot), exp)
    assert run._cache_size() == 1, \
        f"expected exactly one compilation, cache has {run._cache_size()}"


def test_mixed_block_interleaves_all_families():
    vm, params, storage, cfg = W.make_mixed_block(W.MixedSpec(), 64, seed=3)
    codes = np.asarray(params["code"])
    # at least two distinct programs actually present in the block
    assert len({codes[i].tobytes() for i in range(64)}) == 3


def test_chain_of_mixed_blocks():
    """run_chain works unchanged with the bytecode VM (per-block code arrays)."""
    from repro.core.engine import run_chain
    spec = W.MixedSpec(p2p=W.P2PSpec(n_accounts=20))
    n_txns, n_blocks = 24, 3
    blocks = []
    for b in range(n_blocks):
        vm, params, storage0, cfg = W.make_mixed_block(spec, n_txns,
                                                       seed=200 + b)
        blocks.append(params)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    final, results = jax.jit(
        lambda bp, st: run_chain(vm, bp, st, cfg))(stacked, storage0)
    assert bool(np.asarray(results.committed).all())
    state = np.asarray(storage0)
    for b in range(n_blocks):
        state = run_sequential(vm, blocks[b], state, n_txns)
    np.testing.assert_array_equal(np.asarray(final), state)


# ---------------------------------------------------------------------------
# ISA / assembler unit tests
# ---------------------------------------------------------------------------

def test_assembler_counts_and_padding():
    a = Assembler()
    x = a.param(0)
    y = a.read(x)
    a.write(x, a.add(y, a.imm(1)))
    prog = a.build(pad_to=16)
    assert prog.code.shape == (16, 4)
    assert prog.n_reads == 1 and prog.n_writes == 1 and prog.n_params == 1
    assert prog.code[-1, 0] == isa.HALT
    with pytest.raises(ValueError):
        prog.padded(2)  # never truncate


def test_halt_stops_execution():
    """Ops after HALT must have no effect (pad rows are dead)."""
    a = Assembler()
    loc = a.imm(0)
    a.write(loc, a.imm(7))
    a.halt()
    prog = a.build()
    # hand-append a rogue write after HALT
    rogue = np.array([[isa.WRITE, loc, loc, isa.ALWAYS]], np.int32)
    code = np.concatenate([prog.code, rogue])
    vm = BytecodeVM(n_regs=prog.n_regs)
    params = {"code": jnp.asarray(code[None]), "args": jnp.zeros((1, 1), jnp.int32)}
    storage = jnp.zeros(3, jnp.int32)
    cfg = W.EngineConfig(n_txns=1, n_locs=3, max_reads=1, max_writes=2,
                         window=1)
    res = run_block(vm, params, storage, cfg)
    assert bool(res.committed)
    np.testing.assert_array_equal(np.asarray(res.snapshot), [7, 0, 0])


def test_select_and_masked_write():
    """SELECT + enable-masked WRITE: the disabled branch leaves storage."""
    a = Assembler()
    cond = a.param(0)
    picked = a.select(cond, a.imm(111), a.imm(222))
    a.write(a.imm(0), picked)
    a.write(a.imm(1), a.imm(5), enable=cond)     # masked on cond
    prog = a.build()
    vm = BytecodeVM(n_regs=prog.n_regs)
    cfg = W.EngineConfig(n_txns=2, n_locs=2, max_reads=1,
                         max_writes=prog.n_writes, window=2)
    code = np.broadcast_to(prog.code[None], (2,) + prog.code.shape)
    params = {"code": jnp.asarray(np.ascontiguousarray(code)),
              "args": jnp.asarray([[1], [0]], jnp.int32)}
    storage = jnp.full((2,), -3, jnp.int32)
    res = run_block(vm, params, storage, cfg)
    # txn0 (cond=1) writes 111 then txn1 (cond=0) overwrites with 222;
    # loc 1 written only by txn0.
    np.testing.assert_array_equal(np.asarray(res.snapshot), [222, 5])
    exp = run_sequential(vm, params, storage, 2)
    np.testing.assert_array_equal(np.asarray(res.snapshot), exp)


def test_slot_overflow_fails_loudly():
    """A program with more READ ops than cfg.max_reads must NOT commit a
    (potentially unsound) snapshot: the incarnation self-blocks and the
    engine stalls to its wave cap with committed=False."""
    a = Assembler()
    loc = a.imm(1)
    a.read(loc)
    a.read(loc)      # second READ overflows max_reads=1
    a.write(loc, a.imm(3))
    prog = a.build()
    vm = BytecodeVM(n_regs=prog.n_regs)
    cfg = W.EngineConfig(n_txns=1, n_locs=4, max_reads=1, max_writes=1,
                         window=1, max_waves=6)
    params = {"code": jnp.asarray(prog.code[None]),
              "args": jnp.zeros((1, 1), jnp.int32)}
    res = run_block(vm, params, jnp.zeros(4, jnp.int32), cfg)
    assert not bool(res.committed)


def test_disassemble_roundtrip_smoke():
    prog = BC.compile_admission(W.AdmissionSpec(n_tenants=2, n_groups=2,
                                                total_pages=8,
                                                quota_per_tenant=8))
    text = prog.disassemble()
    assert "READ" in text and "WRITE" in text and "HALT" in text

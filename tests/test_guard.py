"""Chaos / guard / degradation property suite (``repro.guard``).

The adversarial half of the determinism story:

* Chaos schedules — a seed grid of ``ChaosConfig`` perturbations (spurious
  aborts, committed-prefix re-execution, corrupted speculative values,
  stalled lanes, deferred validation) must leave the committed snapshot
  byte-identical to the unperturbed sequential baseline, with
  ``committed=True``, on every MV backend and across 1/2/8 virtual devices
  of the dist mesh.
* Guarded degradation — a block that exhausts its wave budget commits the
  preset-order state via the in-jit sequential fallback
  (``BlockResult.degraded``); ``run_chain`` carries the flag per block and
  never feeds a partial snapshot forward.  Blocks that are unsound even
  sequentially (slot overflow) still refuse to commit.
* In-jit invariants — ``guard_level`` 1/2 accumulate a ``GuardReport``
  that stays clean under every chaos schedule; level 0 (the default) is
  property-tested to be the exact unguarded program: byte-identical
  results and zero recompiles.

Dist coverage follows ``tests/test_dist.py``'s convention: mesh tests skip
below 8 devices and the suite re-runs itself in a subprocess with
``--xla_force_host_platform_device_count=8``.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from _hypo import given, settings, st

from repro.core import workloads as W
from repro.core.engine import make_executor, run_block, run_chain
from repro.core.types import EngineConfig
from repro.core.vm import run_sequential
from repro.guard import ChaosConfig, GuardReport, assert_clean, summarize
from repro.guard import invariants as GI
from repro.launch.mesh import make_mesh

jax.config.update("jax_platform_name", "cpu")

REQUIRED = 8
_FLAG = f"--xla_force_host_platform_device_count={REQUIRED}"

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < REQUIRED,
    reason=f"needs {REQUIRED} virtual devices (XLA_FLAGS={_FLAG}); "
    f"covered via the subprocess runner")

BACKENDS = ("dense", "sorted", "sharded")
STATS = ("committed", "degraded", "waves", "execs", "dep_aborts",
         "val_aborts", "wrote_new")


def _stats(res):
    return tuple(int(getattr(res, f)) for f in STATS)


def _block(n_txns=48, seed=3, backend="sorted", **kw):
    shards = dict(n_shards=8) if backend == "sharded" else {}
    return W.make_mixed_block(W.MixedSpec(), n_txns, seed=seed,
                              backend=backend, **shards, **kw)


def _oracle(vm, params, storage, cfg):
    return np.asarray(run_sequential(vm, params, storage, cfg.n_txns))


# ---------------------------------------------------------------------------
# Subprocess runner: tier-1 dist coverage without process-wide XLA flags
# ---------------------------------------------------------------------------

def test_guard_suite_under_virtual_mesh():
    if len(jax.devices()) >= REQUIRED:
        pytest.skip("already on a virtual mesh; suite runs directly")
    env = dict(os.environ, XLA_FLAGS=_FLAG, JAX_PLATFORMS="cpu")
    env.setdefault("REPRO_FAST_EXAMPLES", "2")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=3000)
    assert r.returncode == 0, \
        f"guard suite failed under {_FLAG}:\n{r.stdout[-4000:]}\n" \
        f"{r.stderr[-2000:]}"


# ---------------------------------------------------------------------------
# Config validation: named errors, no silent nonsense
# ---------------------------------------------------------------------------

def test_config_rejects_negative_max_waves():
    with pytest.raises(ValueError, match="max_waves"):
        EngineConfig(n_txns=8, n_locs=64, max_reads=4, max_writes=4,
                     max_waves=-1)
    # 0 stays the documented auto-cap sentinel
    cfg = EngineConfig(n_txns=8, n_locs=64, max_reads=4, max_writes=4,
                       max_waves=0)
    assert cfg.waves_cap() > 0


def test_config_rejects_unknown_guard_level():
    with pytest.raises(ValueError, match="guard_level"):
        EngineConfig(n_txns=8, n_locs=64, max_reads=4, max_writes=4,
                     guard_level=3)


def test_config_rejects_non_chaosconfig():
    with pytest.raises(ValueError, match="chaos"):
        EngineConfig(n_txns=8, n_locs=64, max_reads=4, max_writes=4,
                     chaos={"seed": 1})


def test_chaos_config_rejects_bad_knobs():
    with pytest.raises(ValueError, match="horizon"):
        ChaosConfig(horizon=-1)
    with pytest.raises(ValueError, match="p_stall"):
        ChaosConfig(p_stall=1.5)
    with pytest.raises(ValueError, match="p_recommit"):
        ChaosConfig(p_recommit=-0.1)


# ---------------------------------------------------------------------------
# Chaos schedules: byte-identical committed state on every backend
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), backend=st.sampled_from(BACKENDS))
def test_chaos_commits_sequential_state(seed, backend):
    vm, params, storage, cfg = _block(seed=seed % 7, backend=backend)
    expected = _oracle(vm, params, storage, cfg)
    chaos = ChaosConfig(seed=seed)
    res = run_block(vm, params, storage,
                    dataclasses.replace(cfg, chaos=chaos))
    assert bool(res.committed) and not bool(res.degraded), (seed, backend)
    np.testing.assert_array_equal(np.asarray(res.snapshot), expected,
                                  err_msg=f"seed={seed} {backend}")


def test_chaos_schedule_is_reproducible():
    """Same ChaosConfig => bit-identical run, including the wave count."""
    vm, params, storage, cfg = _block(backend="sharded")
    c = dataclasses.replace(cfg, chaos=ChaosConfig(seed=17))
    a = run_block(vm, params, storage, c)
    b = run_block(vm, params, storage, c)
    assert _stats(a) == _stats(b)
    np.testing.assert_array_equal(np.asarray(a.snapshot),
                                  np.asarray(b.snapshot))


def test_chaos_actually_perturbs():
    """The fixture must not be vacuous: chaos changes the schedule (more
    waves / re-executions than the unperturbed run) even though the
    committed state is unchanged."""
    vm, params, storage, cfg = _block(backend="sharded")
    ref = run_block(vm, params, storage, cfg)
    res = run_block(vm, params, storage, dataclasses.replace(
        cfg, chaos=ChaosConfig(seed=17)))
    assert int(res.waves) > int(ref.waves) or int(res.execs) > int(ref.execs)
    np.testing.assert_array_equal(np.asarray(res.snapshot),
                                  np.asarray(ref.snapshot))


def test_chaos_per_knob_isolation():
    """Each fault class alone preserves the committed state (a regression
    in one knob cannot hide behind the others)."""
    vm, params, storage, cfg = _block(backend="sorted")
    expected = _oracle(vm, params, storage, cfg)
    quiet = dict(p_stall=0.0, p_spurious_abort=0.0, p_recommit=0.0,
                 p_defer_validation=0.0, corrupt_values=False)
    for knob in ("p_stall", "p_spurious_abort", "p_recommit",
                 "p_defer_validation", "corrupt_values"):
        kw = dict(quiet, **{knob: True if knob == "corrupt_values" else 0.7})
        res = run_block(vm, params, storage, dataclasses.replace(
            cfg, chaos=ChaosConfig(seed=23, **kw)))
        assert bool(res.committed), knob
        np.testing.assert_array_equal(np.asarray(res.snapshot), expected,
                                      err_msg=knob)


# ---------------------------------------------------------------------------
# Guarded degradation: every block commits; unsound blocks still refuse
# ---------------------------------------------------------------------------

def test_starved_block_degrades_and_commits():
    vm, params, storage, cfg = _block(backend="sharded")
    expected = _oracle(vm, params, storage, cfg)
    starved = dataclasses.replace(cfg, max_waves=1)
    res = run_block(vm, params, storage, starved)
    assert bool(res.committed) and bool(res.degraded)
    np.testing.assert_array_equal(np.asarray(res.snapshot), expected)
    # a healthy budget never takes the fallback
    res2 = run_block(vm, params, storage, cfg)
    assert bool(res2.committed) and not bool(res2.degraded)


def test_degrade_on_stall_false_restores_old_cliff():
    vm, params, storage, cfg = _block(backend="sorted")
    starved = dataclasses.replace(cfg, max_waves=1, degrade_on_stall=False)
    res = run_block(vm, params, storage, starved)
    assert not bool(res.committed) and not bool(res.degraded)


def test_degraded_trace_flag_and_frontier_stall():
    vm, params, storage, cfg = _block(backend="sorted")
    starved = dataclasses.replace(cfg, max_waves=1, trace_level=1)
    res = run_block(vm, params, storage, starved)
    assert bool(np.asarray(res.trace.degraded))
    from repro.obs import export as X
    d = X.trace_to_dict(res.trace, res.waves)
    assert d["degraded"] is True and "frontier_stall" in d
    # healthy run: flag off; stall counter resets on every advance
    res2 = run_block(vm, params, storage,
                     dataclasses.replace(cfg, trace_level=1))
    assert not bool(np.asarray(res2.trace.degraded))
    w = int(res2.waves)
    fr = np.asarray(res2.trace.frontier)[:w]
    stall = np.asarray(res2.trace.frontier_stall)[:w]
    adv = np.diff(np.concatenate([[0], fr])) > 0
    np.testing.assert_array_equal(stall == 0, adv)


def test_chain_carries_degraded_flag_and_commits():
    """Satellite regression: run_chain must surface committed/degraded per
    block and a starved chain must still end in the sequential state."""
    spec = W.P2PSpec(n_accounts=20)
    n_txns, n_blocks = 32, 3
    cfg = W.p2p_engine_config(spec, n_txns, window=8)
    blocks = []
    for b in range(n_blocks):
        params, storage0 = W.make_p2p_block(spec, n_txns, seed=200 + b)
        blocks.append(params)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    prog = W.p2p_program(spec)

    expected = np.asarray(storage0)
    for b in range(n_blocks):
        expected = run_sequential(prog, blocks[b], expected, n_txns)

    starved = dataclasses.replace(cfg, max_waves=1)
    final, stats = jax.jit(
        lambda bp, st_: run_chain(prog, bp, st_, starved))(stacked, storage0)
    assert bool(np.asarray(stats.committed).all())
    assert bool(np.asarray(stats.degraded).all())
    assert np.asarray(stats.committed).shape == (n_blocks,)
    np.testing.assert_array_equal(np.asarray(final), expected)

    # healthy chain: same state, no degradation
    final2, stats2 = jax.jit(
        lambda bp, st_: run_chain(prog, bp, st_, cfg))(stacked, storage0)
    assert bool(np.asarray(stats2.committed).all())
    assert not bool(np.asarray(stats2.degraded).any())
    np.testing.assert_array_equal(np.asarray(final2), expected)


def test_slot_overflow_still_refuses_to_commit():
    """Degradation must NOT launder unsound blocks: a txn that overflows
    its read budget blocks even sequentially, so committed stays False
    (same fixture as test_bytecode.py::test_slot_overflow_fails_loudly)."""
    from repro.bytecode import BytecodeVM
    from repro.bytecode.assembler import Assembler

    a = Assembler()
    loc = a.imm(1)
    a.read(loc)
    a.read(loc)      # second READ overflows max_reads=1
    a.write(loc, a.imm(3))
    prog = a.build()
    vm = BytecodeVM(n_regs=prog.n_regs)
    cfg = EngineConfig(n_txns=1, n_locs=4, max_reads=1, max_writes=1,
                       window=1, max_waves=6)
    params = {"code": jnp.asarray(prog.code[None]),
              "args": jnp.zeros((1, 1), jnp.int32)}
    res = run_block(vm, params, jnp.zeros(4, jnp.int32), cfg)
    assert not bool(res.committed)
    assert not bool(res.degraded)


# ---------------------------------------------------------------------------
# Guard levels: clean reports under chaos, exact level-0 gating
# ---------------------------------------------------------------------------

def test_guard_level0_is_none_and_exact():
    vm, params, storage, cfg = _block(backend="sharded")
    ref = run_block(vm, params, storage, cfg)
    assert ref.guard is None
    for lvl in (1, 2):
        res = run_block(vm, params, storage,
                        dataclasses.replace(cfg, guard_level=lvl))
        assert isinstance(res.guard, GuardReport)
        assert_clean(res.guard, f"level {lvl}")
        np.testing.assert_array_equal(np.asarray(res.snapshot),
                                      np.asarray(ref.snapshot))
        assert _stats(res) == _stats(ref), lvl


def test_guard_zero_recompiles_across_mixes():
    """The default config compiles ONE program that serves every block —
    chaos=None / guard_level=0 gating must not leak into the cache key."""
    vm, params, storage, cfg = _block()
    run = make_executor(vm, cfg)
    for i, ratios in enumerate([(1, 1, 1), (8, 1, 1), (1, 1, 8)]):
        _, params, storage, _ = W.make_mixed_block(
            W.MixedSpec(ratios=ratios), cfg.n_txns, seed=30 + i)
        res = run(params, storage)
        assert bool(res.committed)
    assert run._cache_size() == 1, run._cache_size()


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**16), backend=st.sampled_from(BACKENDS))
def test_guard_stays_clean_under_chaos(seed, backend):
    """Level-2 invariants hold on every chaos schedule — the adversarial
    runs are exactly where a broken invariant would surface."""
    vm, params, storage, cfg = _block(seed=seed % 5, backend=backend)
    res = run_block(vm, params, storage, dataclasses.replace(
        cfg, guard_level=2, chaos=ChaosConfig(seed=seed)))
    assert bool(res.committed)
    assert_clean(res.guard, f"chaos seed={seed} {backend}")
    s = summarize(res.guard)
    assert set(s) == set(GI.INVARIANTS)
    assert all(d["first_wave"] == -1 for d in s.values())


def test_guard_detects_planted_violation():
    """The checks must be able to fire: hand check_wave a state whose
    frontier retreats and whose incarnations are out of bounds."""
    vm, params, storage, cfg = _block(n_txns=16, backend="sorted")
    gcfg = dataclasses.replace(cfg, guard_level=2)
    from repro.core.engine import _init_state
    state = jax.jit(lambda: _init_state(gcfg))()
    state = state._replace(
        frontier=jnp.asarray(5, jnp.int32),
        incarnation=state.incarnation.at[3].set(99))
    checked = GI.check_wave(state, gcfg, jnp.asarray(2, jnp.int32),
                            skip_viol=jnp.asarray(4, jnp.int32))
    s = summarize(checked.guard)
    assert s["frontier_monotone"]["violations"] == 1
    assert s["incarnation_bound"]["violations"] == 1
    assert s["dirty_skip_sound"]["violations"] == 4
    assert s["frontier_monotone"]["first_wave"] == 0
    with pytest.raises(AssertionError, match="frontier_monotone"):
        assert_clean(checked.guard)


# ---------------------------------------------------------------------------
# Dist mesh: chaos + guard + degradation across 1/2/8 virtual devices
# ---------------------------------------------------------------------------

@needs_mesh
def test_dist_chaos_matches_single_device():
    vm, params, storage, cfg = _block(n_txns=64, backend="sharded",
                                      n_locs=50_000, zipf_s=1.1)
    base = dataclasses.replace(cfg, chaos=ChaosConfig(seed=29),
                               guard_level=2)
    ref = run_block(vm, params, storage, base)
    assert bool(ref.committed)
    assert_clean(ref.guard, "single-device chaos")
    for d in (1, 2, 8):
        dcfg = dataclasses.replace(base, dist=True,
                                   mesh=make_mesh("regions", (d,)))
        res = run_block(vm, params, storage, dcfg)
        np.testing.assert_array_equal(np.asarray(res.snapshot),
                                      np.asarray(ref.snapshot),
                                      err_msg=f"D={d}")
        assert _stats(res) == _stats(ref), d
        assert_clean(res.guard, f"D={d}")


@needs_mesh
def test_dist_degradation_commits():
    vm, params, storage, cfg = _block(n_txns=32, backend="sharded")
    expected = _oracle(vm, params, storage, cfg)
    for d in (2, 8):
        dcfg = dataclasses.replace(cfg, max_waves=1, dist=True,
                                   mesh=make_mesh("regions", (d,)))
        res = run_block(vm, params, storage, dcfg)
        assert bool(res.committed) and bool(res.degraded), d
        np.testing.assert_array_equal(np.asarray(res.snapshot), expected,
                                      err_msg=f"D={d}")

"""Substrate tests: checkpoint manager (atomic/async/keep-K/elastic),
deterministic data pipeline, optimizer, fault-tolerance utilities."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch, reduced_config
from repro.data.pipeline import SyntheticLMStream
from repro.distributed.fault_tolerance import (PreemptionHandler,
                                               StragglerMonitor)
from repro.models import model as MDL
from repro.optim import adamw
from repro.runtime import steps as RT

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _small_state():
    cfg = reduced_config(get_arch("gemma-2b"))
    opt_cfg = adamw.AdamWConfig()
    return cfg, opt_cfg, RT.init_train_state(
        jax.random.PRNGKey(0), cfg, opt_cfg, jnp.float32)


def test_checkpoint_roundtrip(tmp_path):
    cfg, opt_cfg, state = _small_state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(10, state, extra={"data_step": 11}, blocking=True)
    restored, meta = mgr.restore(state)
    assert meta["step"] == 10 and meta["extra"]["data_step"] == 11
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    _, _, state = _small_state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    _, _, state = _small_state()
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, state)          # async
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    _, _, state = _small_state()
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(7, state, blocking=True)
    names = os.listdir(tmp_path)
    assert all(not n.startswith(".tmp") for n in names), names


def test_elastic_restore_resharding(tmp_path):
    """Save, then restore with explicit (different) shardings: elastic resume."""
    _, _, state = _small_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state.params, blocking=True)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), state.params)
    restored, _ = mgr.restore(state.params, shardings=shardings)
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.sharding == NamedSharding(mesh, P())


def test_train_restart_is_bitexact(tmp_path):
    """Kill/restart mid-run must reproduce the uninterrupted run exactly
    (checkpoint + deterministic data stream)."""
    cfg = reduced_config(get_arch("gemma-2b"))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=8, warmup_steps=1)

    def run(n_steps, state, stream):
        step_fn = jax.jit(RT.make_train_step(cfg, opt_cfg))
        for _ in range(n_steps):
            state, _ = step_fn(state, stream.next_batch())
        return state

    # uninterrupted: 6 steps
    s0 = RT.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg, jnp.float32)
    full = run(6, s0, SyntheticLMStream(cfg, 2, 16, seed=0))

    # interrupted at 3 + restart from checkpoint
    s1 = RT.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg, jnp.float32)
    stream = SyntheticLMStream(cfg, 2, 16, seed=0)
    s1 = run(3, s1, stream)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, s1, extra={"data_step": stream.state.step}, blocking=True)

    template = RT.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg,
                                   jnp.float32)
    restored, meta = mgr.restore(template)
    stream2 = SyntheticLMStream(cfg, 2, 16, seed=0,
                                start_step=meta["extra"]["data_step"])
    resumed = run(3, restored, stream2)

    for a, b in zip(jax.tree_util.tree_leaves(full.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_resume():
    cfg = reduced_config(get_arch("gemma-2b"))
    a = SyntheticLMStream(cfg, 4, 32, seed=1)
    b1 = [a.next_batch() for _ in range(3)]
    b = SyntheticLMStream(cfg, 4, 32, seed=1, start_step=2)
    resumed = b.next_batch()
    np.testing.assert_array_equal(np.asarray(b1[2]["tokens"]),
                                  np.asarray(resumed["tokens"]))


def test_data_labels_are_shifted_tokens():
    cfg = reduced_config(get_arch("gemma-2b"))
    s = SyntheticLMStream(cfg, 2, 16, seed=0)
    batch = s.next_batch()
    assert batch["tokens"].shape == (2, 16)
    assert batch["labels"].shape == (2, 16)
    assert (np.asarray(batch["tokens"]) < cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=200,
                            warmup_steps=1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adamw_grad_clipping():
    cfg = adamw.AdamWConfig(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params, cfg)
    _, _, m = adamw.update({"w": jnp.full(4, 100.0)}, state, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_adamw_bf16_state_compression():
    cfg = adamw.AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones(8, jnp.float32)}
    state = adamw.init(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16
    p2, s2, _ = adamw.update({"w": jnp.ones(8)}, state, params, cfg)
    assert s2.m["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_schedule_warmup_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    assert float(adamw.schedule(jnp.asarray(5), cfg)) == pytest.approx(0.5)
    assert float(adamw.schedule(jnp.asarray(10), cfg)) == pytest.approx(1.0)
    assert float(adamw.schedule(jnp.asarray(100), cfg)) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_preemption_handler():
    h = PreemptionHandler(signals=(signal.SIGUSR1,))
    assert not h.preempted
    os.kill(os.getpid(), signal.SIGUSR1)
    assert h.preempted
    h.restore()


def test_straggler_monitor_flags_slow_steps():
    import time
    mon = StragglerMonitor(threshold=5.0, patience=2, warmup=2)
    for step in range(12):
        mon.start_step()
        time.sleep(0.012 if step in (8, 9, 10) else 0.001)
        mon.end_step(step)
    assert mon.flagged, "slow steps were not flagged"

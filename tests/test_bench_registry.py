"""Benchmark-observatory suite: registry, gate, history, cost accounting.

* Registration — suites/benchmarks/metrics declare once; duplicate names
  and bad metric specs are registration errors, and importing the repo's
  suite modules yields all six suites with non-empty contracts.
* Gate — ``benchmarks.check_regression.compare_records`` on SYNTHETIC
  records: banded pass/fail in both directions, exact-metric drift,
  grid-drift notes, int32-refusal flips, vanished metrics, and the
  fast-vs-full aggregate refusal (:class:`IncomparableRunsError`).
* History — append/load round-trip of the commit-stamped trajectory
  lines, stale-schema partitioning, and the dashboard's trend-table
  renderer (``repro.obs.report``).
* Cost — ``repro.obs.cost``'s routed-exchange decomposition on synthetic
  cost records, plus the real thing: the dist execute phase is lowered on
  a 2-device mesh and its HLO-walked all-to-all bytes must reproduce the
  hand-computed ``routed_read_bytes_per_device`` exactly.

The mesh half needs ``--xla_force_host_platform_device_count=2`` BEFORE
jax initializes, which a shared pytest process cannot guarantee — so when
this process has fewer than 2 devices,
:func:`test_bench_suite_under_virtual_mesh` re-runs this file in a
subprocess with the flag set (the ``tests/test_dist.py`` convention).
"""
import json
import os
import subprocess
import sys

import pytest

import jax

from benchmarks import history
from benchmarks import registry as REG
from benchmarks._emit import (SCHEMA_REV, IncomparableRunsError, load_bench,
                              write_bench)
from benchmarks.check_regression import compare_records

jax.config.update("jax_platform_name", "cpu")

REQUIRED = 2
_FLAG = f"--xla_force_host_platform_device_count={REQUIRED}"

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < REQUIRED,
    reason=f"needs {REQUIRED} virtual devices (XLA_FLAGS={_FLAG}); "
    f"covered via the subprocess runner")


# ---------------------------------------------------------------------------
# Subprocess runner: tier-1 coverage without process-wide XLA flags
# ---------------------------------------------------------------------------

def test_bench_suite_under_virtual_mesh():
    if len(jax.devices()) >= REQUIRED:
        pytest.skip("already on a virtual mesh; suite runs directly")
    env = dict(os.environ, XLA_FLAGS=_FLAG, JAX_PLATFORMS="cpu")
    env.setdefault("REPRO_FAST_EXAMPLES", "2")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=3000)
    assert r.returncode == 0, \
        f"bench-registry suite failed under {_FLAG}:\n{r.stdout[-4000:]}\n" \
        f"{r.stderr[-2000:]}"


# ---------------------------------------------------------------------------
# Registration contract
# ---------------------------------------------------------------------------

@pytest.fixture
def scratch_suite():
    name = "_scratch"
    suite = REG.register_suite(name, doc="test-only suite")
    try:
        yield suite
    finally:
        REG._SUITES.pop(name, None)


def test_duplicate_suite_rejected(scratch_suite):
    with pytest.raises(REG.BenchRegistryError, match="already registered"):
        REG.register_suite(scratch_suite.name)


def test_benchmark_registration_and_duplicate(scratch_suite):
    @REG.register_benchmark(scratch_suite, "ab", impls=("left", "right"))
    def _ab(ctx):
        """One A/B."""

    b = scratch_suite.benchmarks["ab"]
    assert b.impls == ("left", "right")
    assert b.doc == "One A/B."
    with pytest.raises(REG.BenchRegistryError, match="already registered"):
        REG.register_benchmark(scratch_suite, "ab")(lambda ctx: None)


def test_metric_registration_and_validation(scratch_suite):
    m = REG.register_metric(scratch_suite, "tps", tolerance=5.0)
    assert m.direction == "higher" and m.scope == "record"
    with pytest.raises(REG.BenchRegistryError, match="already registered"):
        REG.register_metric(scratch_suite, "tps")
    with pytest.raises(REG.BenchRegistryError, match="direction"):
        REG.register_metric(scratch_suite, "bad", direction="sideways")
    with pytest.raises(REG.BenchRegistryError, match="scope"):
        REG.register_metric(scratch_suite, "bad", scope="galaxy")
    with pytest.raises(REG.BenchRegistryError, match="unknown suite"):
        REG.get_suite("_no_such_suite")


def test_all_repo_suites_register():
    suites = REG.all_suites()
    assert {"bytecode", "baselines", "shards", "hotpath", "dist",
            "guard"} <= set(suites)
    for s in suites.values():
        assert s.benchmarks, f"suite {s.name} has no benchmarks"
        assert s.metrics, f"suite {s.name} has no gated metrics"
    assert suites["dist"].needs_devices == 8
    assert suites["guard"].extra_gate is not None


def test_dig_dotted_paths():
    d = {"a": {"b": {"c": 3}}, "x": 1}
    assert REG._dig(d, "a.b.c") == 3
    assert REG._dig(d, "x") == 1
    assert REG._dig(d, "a.b.missing") is None
    assert REG._dig(d, "x.deeper") is None


# ---------------------------------------------------------------------------
# Gate semantics on synthetic records (no benchmark execution)
# ---------------------------------------------------------------------------

def _toy_suite(aggregate=False):
    s = REG.Suite("toy")
    s.metrics = {
        "tps": REG.Metric("tps"),
        "overhead_x": REG.Metric("overhead_x", direction="lower"),
        "misses": REG.Metric("misses", direction="exact"),
        "sub.tps": REG.Metric("sub.tps", scope="cell"),
        "waves": REG.Metric("waves", direction="exact", scope="cell"),
    }
    if aggregate:
        s.metrics["median_x"] = REG.Metric("median_x", aggregate=True)
    return s


def _rec(run=None, **kw):
    rec = {"suite": "toy", "schema_rev": SCHEMA_REV,
           "run": run or {"mode": "fast", "params": {"n": 4}},
           "tps": 1000.0, "overhead_x": 2.0, "misses": 0,
           "grid": {"c0": {"sub": {"tps": 500.0}, "waves": 3}}}
    rec.update(kw)
    return rec


def test_gate_identical_records_pass():
    failures, notes = compare_records(_toy_suite(), _rec(), _rec())
    assert not failures
    assert any("waves" in n for n in notes)   # exact metrics reported


def test_gate_banded_regressions_both_directions():
    # higher-is-better collapsing 20x fails; 2x is inside the 10x band
    failures, _ = compare_records(_toy_suite(), _rec(), _rec(tps=50.0))
    assert any("tps" in f and "regression" in f for f in failures)
    failures, _ = compare_records(_toy_suite(), _rec(), _rec(tps=500.0))
    assert not failures
    # lower-is-better blowing up 20x fails; improving never fails
    failures, _ = compare_records(_toy_suite(), _rec(),
                                  _rec(overhead_x=40.0))
    assert any("overhead_x" in f for f in failures)
    failures, _ = compare_records(_toy_suite(), _rec(),
                                  _rec(overhead_x=0.1))
    assert not failures
    # per-metric tolerance wins over the default band
    s = _toy_suite()
    s.metrics["tps"] = REG.Metric("tps", tolerance=2.0)
    failures, _ = compare_records(s, _rec(), _rec(tps=400.0))
    assert any("tps" in f for f in failures)


def test_gate_exact_metrics_fail_on_any_drift():
    failures, _ = compare_records(_toy_suite(), _rec(), _rec(misses=1))
    assert any("misses" in f and "structural drift" in f for f in failures)
    fresh = _rec()
    fresh["grid"]["c0"]["waves"] = 4
    failures, _ = compare_records(_toy_suite(), _rec(), fresh)
    assert any("c0.waves" in f for f in failures)
    # ... but only between comparable runs
    fresh["run"] = {"mode": "full", "params": {"n": 64}}
    failures, notes = compare_records(_toy_suite(), _rec(), fresh)
    assert not failures
    assert any("not comparable" in n for n in notes)


def test_gate_dotted_cell_metric():
    fresh = _rec()
    fresh["grid"]["c0"]["sub"] = {"tps": 10.0}    # 50x cell collapse
    failures, _ = compare_records(_toy_suite(), _rec(), fresh)
    assert any("c0.sub.tps" in f for f in failures)


def test_gate_grid_drift_noted_not_failed():
    fresh = _rec()
    fresh["grid"]["c1"] = {"sub": {"tps": 1.0}, "waves": 9}
    failures, notes = compare_records(_toy_suite(), _rec(), fresh)
    assert not failures
    assert any("grid drift" in n for n in notes)


def test_gate_refusal_flip_fails_when_comparable():
    fresh = _rec()
    fresh["grid"]["c0"] = {"error": "int32 key bound exceeded"}
    failures, _ = compare_records(_toy_suite(), _rec(), fresh)
    assert any("refusal state changed" in f for f in failures)
    fresh["run"] = {"mode": "full", "params": {}}
    failures, notes = compare_records(_toy_suite(), _rec(), fresh)
    assert not failures
    assert any("refusal state changed" in n for n in notes)


def test_gate_vanished_metric_fails_new_metric_notes():
    fresh = _rec()
    del fresh["tps"]
    failures, _ = compare_records(_toy_suite(), _rec(), fresh)
    assert any("missing in fresh" in f for f in failures)
    base = _rec()
    del base["tps"]
    failures, notes = compare_records(_toy_suite(), base, _rec())
    assert not failures
    assert any("new metric" in n for n in notes)


def test_gate_refuses_incomparable_aggregates():
    base = _rec(median_x=3.0)
    fresh = _rec(median_x=3.0,
                 run={"mode": "full", "params": {"n": 4096}})
    with pytest.raises(IncomparableRunsError, match="median_x"):
        compare_records(_toy_suite(aggregate=True), base, fresh)
    # without aggregates the same pair is gated (band metrics only)
    failures, _ = compare_records(_toy_suite(), base, fresh)
    assert not failures


# ---------------------------------------------------------------------------
# Emitter + history round-trips
# ---------------------------------------------------------------------------

def test_emit_schema_handshake(tmp_path):
    path = write_bench("toy", {"tps": 1.0}, out=str(tmp_path / "r.json"),
                       mode="fast", params={"n": 4})
    rec = load_bench(path, expect_suite="toy")
    assert rec["schema_rev"] == SCHEMA_REV
    assert rec["run"] == {"mode": "fast", "params": {"n": 4}}
    assert rec["env"]["device_count"] == len(jax.devices())
    with pytest.raises(ValueError, match="expected 'other'"):
        load_bench(path, expect_suite="other")
    rec["schema_rev"] = SCHEMA_REV - 1
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps(rec))
    with pytest.raises(ValueError, match="schema_rev"):
        load_bench(str(stale))


def test_unstamped_record_never_comparable(tmp_path):
    stamped = load_bench(write_bench(
        "toy", {"tps": 1.0}, out=str(tmp_path / "a.json"), mode="fast"))
    raw = load_bench(write_bench(
        "toy", {"tps": 1.0}, out=str(tmp_path / "b.json")))
    assert raw["run"]["mode"] == "unknown"
    s = _toy_suite(aggregate=True)
    with pytest.raises(IncomparableRunsError):
        compare_records(s, stamped, raw)


def test_history_round_trip_and_schema_partition(tmp_path):
    p = str(tmp_path / "hist.jsonl")
    with open(p, "w") as f:     # one stale-schema line already present
        f.write(json.dumps({"schema_rev": SCHEMA_REV - 1,
                            "suite": "toy", "metrics": {}}) + "\n")
    line = history.append(_rec(), {"tps": 1000.0}, path=p)
    assert line["suite"] == "toy" and line["mode"] == "fast"
    assert line["sha"]          # stamped inside a git checkout
    lines = history.load(p)
    assert len(lines) == 2 and lines[-1] == line
    cur, stale = history.partition_by_schema(lines)
    assert stale == 1 and cur == [line]
    assert history.load(str(tmp_path / "missing.jsonl")) == []


def test_history_metrics_flatten():
    s = _toy_suite()
    rec = _rec(grid={"c0": {"sub": {"tps": 100.0}, "waves": 3},
                     "c1": {"sub": {"tps": 300.0}, "waves": 3},
                     "c2": {"error": "refused"}})
    m = REG.history_metrics(s, rec)
    assert m["tps"] == 1000.0 and m["misses"] == 0
    assert m["median_sub_tps"] == 200.0       # error cells excluded
    assert m["median_waves"] == 3             # exact + unanimous -> kept
    rec["grid"]["c1"]["waves"] = 5            # exact + split -> dropped
    assert "median_waves" not in REG.history_metrics(s, rec)


def test_dashboard_trend_tables():
    from repro.obs.report import history_tables
    lines = [{"sha": "abc1234", "dirty": False, "suite": "toy",
              "schema_rev": SCHEMA_REV, "mode": "fast", "platform": "cpu",
              "metrics": {"tps": 1000.0}},
             {"sha": "def5678", "dirty": True, "suite": "toy",
              "schema_rev": SCHEMA_REV, "mode": "fast", "platform": "cpu",
              "metrics": {"tps": 1250.0, "misses": 0}}]
    out = history_tables(lines)
    assert "[toy] 2 run(s)" in out
    assert "def5678*" in out                  # dirty worktree marker
    row0 = next(l for l in out.splitlines() if "abc1234" in l)
    assert row0.rstrip().endswith("-")        # later-added metric backfills
    assert "no history lines" in history_tables([])


def test_run_suite_stamps_record_and_history(tmp_path):
    name = "_scratch_run"
    s = REG.register_suite(name, doc="end-to-end scratch suite")
    try:
        @REG.register_benchmark(s, "unit")
        def _unit(ctx):
            n = ctx.size(4, 64, key="n")
            ctx.record["tps"] = 100.0 * n
            ctx.record["grid"] = {"c0": {"waves": 2}}
            ctx.rows.append(("unit", n))

        REG.register_metric(s, "tps")
        REG.register_metric(s, "waves", scope="cell", direction="exact")
        hist = str(tmp_path / "hist.jsonl")
        rows = []
        record, path = REG.run_suite(
            name, fast=True, out=str(tmp_path / "r.json"),
            history_path=hist, rows=rows)
        # the returned record is the STAMPED one consumers load
        assert record == load_bench(path, expect_suite=name)
        assert record["run"] == {"mode": "fast", "params": {"n": 4}}
        assert rows == [("unit", 4)]
        lines = history.load(hist)
        assert len(lines) == 1
        assert lines[0]["metrics"] == {"tps": 400.0, "median_waves": 2}
        # a suite run gates cleanly against itself
        failures, _ = compare_records(s, record, record)
        assert not failures
        # benchmark filtering: nothing selected -> empty record body
        record2, _ = REG.run_suite(name, fast=True,
                                   out=str(tmp_path / "r2.json"),
                                   append_history=False, benchmarks=[])
        assert "tps" not in record2
        assert history.load(hist) == lines    # no new line
    finally:
        REG._SUITES.pop(name, None)


# ---------------------------------------------------------------------------
# Cost accounting: synthetic decomposition + the compiled-artifact check
# ---------------------------------------------------------------------------

def test_routed_exchange_stats_synthetic():
    from repro.obs import cost as C
    # two 7-array exchanges on a 2-device mesh, 704 B each
    rec = {"collective_counts": {"all-to-all": 2 * C.A2A_ARRAYS_PER_EXCHANGE},
           "collectives": {"all-to-all": 2 * 704.0}}
    stats = C.routed_exchange_stats(rec, devices=2)
    assert stats == {"n_exchanges": 2, "bytes_per_exchange": 704.0,
                     "bucket_bytes_per_device": 352.0}
    out = C.crosscheck_routed_read_bytes(rec, 2, max_reads=8,
                                         expected_per_device=8 * 352)
    assert out["routed_read_bytes_per_device_hlo"] == 2816
    with pytest.raises(ValueError, match="!= hand-computed"):
        C.crosscheck_routed_read_bytes(rec, 2, 8, 2817)
    bad = {"collective_counts": {"all-to-all": 13},
           "collectives": {"all-to-all": 1.0}}
    with pytest.raises(ValueError, match="do not decompose"):
        C.routed_exchange_stats(bad, devices=2)


def test_cache_misses_probe():
    from repro.obs import cost as C

    class _Jitted:
        def _cache_size(self):
            return 3

    assert C.cache_misses(_Jitted(), expected_compiles=1) == 2
    assert C.cache_misses(lambda: None) == -1   # no jit cache -> visible gap


@needs_mesh
def test_hlo_collective_bytes_match_hand_computed_payload():
    """The tentpole cross-check, end to end on a real 2-device mesh: lower
    the dist execute phase, walk its post-SPMD HLO, and require the
    all-to-all-derived routed payload to equal PR 7's hand-computed
    ``routed_read_bytes_per_device`` exactly."""
    import dataclasses

    from benchmarks import dist_bench as DB
    from repro.core import workloads as W
    from repro.launch.mesh import make_mesh
    from repro.obs import cost as C

    REG.load_suites()
    suite = REG.get_suite("dist")
    ctx = REG.RunContext(fast=True, params={"n_txns": 128})
    suite.benchmarks["exchange_cost"].fn(ctx)

    d = ctx.record["cost_devices"]
    assert d >= 2
    ex = ctx.record["cost"]["execute"]
    rx = ex["routed_exchange"]
    # the exchange structure decomposes into whole 7-array exchanges
    assert ex["collective_counts"]["all-to-all"] == \
        rx["n_exchanges"] * C.A2A_ARRAYS_PER_EXCHANGE
    assert rx["bytes_per_exchange"] == d * rx["bucket_bytes_per_device"]

    # independently rebuild the hand-computed side from the same cell
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), 128, seed=7, n_locs=10**5, zipf_s=1.1,
        backend="sharded", n_shards=DB.REGIONS_PER_DEVICE * d)
    dcfg = dataclasses.replace(cfg, dist=True,
                               mesh=make_mesh("regions", (d,)))
    expected = DB.exec_lane_stats(dcfg, d)["routed_read_bytes_per_device"]
    assert rx["routed_read_bytes_per_device_hlo"] == expected
    assert rx["bucket_bytes_per_device"] * dcfg.max_reads == expected
    # and the gate holds it: the metric is declared exact on the record
    m = suite.metrics[
        "cost.execute.routed_exchange.routed_read_bytes_per_device_hlo"]
    assert m.direction == "exact"
    assert REG._dig(ctx.record, m.name) == expected

"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step + decode steps on CPU; asserts shapes and finiteness.

Also: decode ≡ teacher-forced forward (cache correctness) for one arch per
family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, reduced_config
from repro.configs.base import ShapeConfig
from repro.models import model as MDL
from repro.optim import adamw
from repro.runtime import steps as RT

jax.config.update("jax_platform_name", "cpu")

ARCH_NAMES = sorted(ARCHS)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_train_step(name):
    cfg = reduced_config(ARCHS[name])
    params = MDL.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    batch = MDL.make_host_batch(cfg, batch=2, seq=16)

    logits, aux = MDL.train_logits(params, batch, cfg)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt_cfg = adamw.AdamWConfig(total_steps=10, warmup_steps=2)
    state = RT.TrainState(params=params, opt=adamw.init(params, opt_cfg),
                          step=jnp.zeros((), jnp.int32))
    step_fn = jax.jit(RT.make_train_step(cfg, opt_cfg))
    state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    state, metrics2 = step_fn(state, batch)
    assert float(metrics2["loss"]) < float(metrics["loss"]) + 1.0  # sane


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_steps(name):
    cfg = reduced_config(ARCHS[name])
    params = MDL.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    cache = MDL.init_cache(cfg, batch=2, max_seq=8, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t: MDL.decode_step(p, c, t, cfg))
    toks = jnp.asarray([1, 2], jnp.int32)
    for i in range(3):
        logits, cache = step(params, cache, toks + i)
        assert logits.shape == (2, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("name", ["gemma-2b", "qwen1.5-110b",
                                  "falcon-mamba-7b", "zamba2-1.2b",
                                  "qwen3-moe-30b-a3b"])
def test_decode_matches_teacher_forcing(name):
    cfg = reduced_config(ARCHS[name])
    params = MDL.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(1)
    s = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s)), jnp.int32)
    full_logits, _ = MDL.train_logits(params, {"tokens": toks}, cfg)
    cache = MDL.init_cache(cfg, batch=2, max_seq=s, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t: MDL.decode_step(p, c, t, cfg))
    outs = []
    for t in range(s):
        lg, cache = step(params, cache, toks[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_training_reduces_loss():
    """A few steps of real optimization must reduce loss on a repeated batch."""
    cfg = reduced_config(get_arch("gemma-2b"))
    opt_cfg = adamw.AdamWConfig(lr=1e-2, total_steps=30, warmup_steps=1,
                                weight_decay=0.0)
    state = RT.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg,
                                jnp.float32)
    batch = MDL.make_host_batch(cfg, batch=4, seq=16)
    step_fn = jax.jit(RT.make_train_step(cfg, opt_cfg))
    losses = []
    for _ in range(12):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_accumulation_matches_full_batch():
    """Grad accumulation must be numerically equivalent to the full batch."""
    cfg = reduced_config(get_arch("gemma-2b"))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    batch = MDL.make_host_batch(cfg, batch=4, seq=8)
    outs = []
    for mb in (1, 2, 4):
        state = RT.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg,
                                    jnp.float32)
        step_fn = jax.jit(RT.make_train_step(cfg, opt_cfg, microbatches=mb))
        state, m = step_fn(state, batch)
        outs.append((float(m["loss"]),
                     np.asarray(jax.tree_util.tree_leaves(state.params)[0])))
    for loss, leaf in outs[1:]:
        assert abs(loss - outs[0][0]) < 1e-4
        np.testing.assert_allclose(leaf, outs[0][1], atol=1e-4)


def test_param_counts_are_plausible():
    """Analytic param counts should be within 2x of their nameplate size."""
    expect = {
        "pixtral-12b": 12e9, "qwen3-moe-30b-a3b": 30e9,
        "qwen1.5-110b": 110e9, "yi-34b": 34e9, "nemotron-4-340b": 340e9,
        "gemma-2b": 2.5e9, "falcon-mamba-7b": 7e9, "zamba2-1.2b": 1.2e9,
    }
    for name, nominal in expect.items():
        n = get_arch(name).param_count()
        assert nominal / 2 < n < nominal * 2.6, (name, n, nominal)

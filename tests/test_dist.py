"""Multi-device Block-STM property suite (``repro.core.dist``).

* Exactness — the dist engine (MV regions shard_mapped over a 1-D
  ``'regions'`` mesh) must commit BYTE-IDENTICAL snapshots and IDENTICAL
  abort/wave statistics to the single-device ``sharded`` backend, on meshes
  of 1/2/8 virtual devices, including region counts that do not divide the
  device count and every engine maintenance/validation variant.
* Routing — the two-hop ``all_to_all`` routed ``resolve_batch`` must agree
  query-for-query with the vmapped single-device resolver.
* Compile-once — one jitted executor per fixed mesh serves every contract
  mix (zero recompiles, via the jit cache size).
* Scale — a 10M-location Zipfian block (beyond the flat int32 key bound)
  executes on the mesh to a snapshot byte-identical with ``run_sequential``.

Virtual devices need ``--xla_force_host_platform_device_count=8`` BEFORE jax
initializes, which a shared pytest process cannot guarantee — so when this
process has fewer than 8 devices, :func:`test_dist_suite_under_virtual_mesh`
re-runs this file in a subprocess with the flag set (the CI ``test-dist``
job sets it process-wide instead and runs the suite directly).
"""
import dataclasses
import functools
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from _hypo import given, settings, st

from repro.core import mv
from repro.core import workloads as W
from repro.core.engine import make_executor, run_block
from repro.core.executor import run_engine
from repro.core.types import EngineConfig
from repro.core.vm import run_sequential
from repro.launch.mesh import make_mesh

jax.config.update("jax_platform_name", "cpu")

REQUIRED = 8
_FLAG = f"--xla_force_host_platform_device_count={REQUIRED}"

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < REQUIRED,
    reason=f"needs {REQUIRED} virtual devices (XLA_FLAGS={_FLAG}); "
    f"covered via the subprocess runner")

STATS = ("committed", "waves", "execs", "dep_aborts", "val_aborts",
         "wrote_new")


def _stats(res):
    return tuple(int(getattr(res, f)) for f in STATS)


# ---------------------------------------------------------------------------
# Subprocess runner: tier-1 coverage without process-wide XLA flags
# ---------------------------------------------------------------------------

def test_dist_suite_under_virtual_mesh():
    if len(jax.devices()) >= REQUIRED:
        pytest.skip("already on a virtual mesh; suite runs directly")
    env = dict(os.environ, XLA_FLAGS=_FLAG, JAX_PLATFORMS="cpu")
    env.setdefault("REPRO_FAST_EXAMPLES", "2")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=3000)
    assert r.returncode == 0, \
        f"dist suite failed under {_FLAG}:\n{r.stdout[-4000:]}\n" \
        f"{r.stderr[-2000:]}"


# ---------------------------------------------------------------------------
# Config validation + generic mesh construction (device-count independent)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(n_txns=0), "n_txns"),
    (dict(n_txns=-3), "n_txns"),
    (dict(n_locs=0), "n_locs"),
    (dict(max_reads=0), "max_reads"),
    (dict(max_writes=0), "max_writes"),
    (dict(window=0), "window"),
    (dict(window=-2), "window"),
    (dict(validation_window=-1), "validation_window"),
])
def test_config_rejects_nonsense_shapes(kw, match):
    """Degenerate extents must refuse at construction with a named error,
    not surface later as an opaque XLA shape failure (or a zero-progress
    while_loop running to the wave cap)."""
    base = dict(n_txns=8, n_locs=64, max_reads=4, max_writes=4)
    base.update(kw)
    with pytest.raises(ValueError, match=match):
        EngineConfig(**base)


def test_config_rejects_dist_without_sharded_backend():
    with pytest.raises(ValueError, match="sharded"):
        EngineConfig(n_txns=8, n_locs=64, max_reads=4, max_writes=4,
                     backend="sorted", dist=True)


def test_config_rejects_mesh_without_dist():
    with pytest.raises(ValueError, match="dist"):
        EngineConfig(n_txns=8, n_locs=64, max_reads=4, max_writes=4,
                     backend="sharded", mesh=make_mesh("regions", (1,)))


def test_config_rejects_wrong_mesh_axis():
    with pytest.raises(ValueError, match="regions"):
        EngineConfig(n_txns=8, n_locs=64, max_reads=4, max_writes=4,
                     backend="sharded", dist=True,
                     mesh=make_mesh("model", (1,)))


def test_run_engine_rejects_mesh_for_baselines():
    vm, params, storage, cfg = W.make_mixed_block(W.MixedSpec(), 8, seed=0)
    with pytest.raises(NotImplementedError, match="single-device"):
        run_engine("litm", vm, params, storage, cfg,
                   mesh=make_mesh("regions", (1,)))


def test_make_mesh_generic():
    n = len(jax.devices())
    m = make_mesh("regions")
    assert m.axis_names == ("regions",) and m.devices.size == n
    m1 = make_mesh("regions", (1,))
    assert m1.devices.size == 1
    # submeshes take a deterministic prefix of the device list
    assert m1.devices.flat[0] == m.devices.flat[0]
    hosty = make_mesh(("data", "model"), (-1, 1))
    assert hosty.axis_names == ("data", "model")
    assert hosty.devices.shape == (n, 1)
    with pytest.raises(ValueError, match="devices"):
        make_mesh("regions", (n + 1,))
    with pytest.raises(ValueError, match="-1"):
        make_mesh(("a", "b"), (-1, -1))


def test_import_dist_is_device_lazy():
    """core/dist follows launch/mesh.py's convention: importing it must not
    construct meshes or touch devices (meshes are built at trace time)."""
    import repro.core.dist as dist
    assert dist.AXIS == "regions"
    # the plan is pure Python: computable without any mesh at all
    plan = dist.plan_for(n_locs=100, n_txns=8, n_shards=6, n_devices=4)
    assert (plan.n_regions, plan.regions_per_device) == (6, 2)
    assert plan.span == plan.regions_per_device * plan.shard_size
    # non-dividing region counts pad the tail device with phantom regions
    assert dist.plan_for(100, 8, 5, 4).regions_per_device == 2


# ---------------------------------------------------------------------------
# Routed resolve: two-hop all_to_all == vmapped single-device resolver
# ---------------------------------------------------------------------------

@needs_mesh
def test_routed_resolve_matches_single_device():
    n_txns, n_locs, w, n_shards = 16, 40, 2, 5
    rng = np.random.default_rng(0)
    write_locs = jnp.asarray(
        np.where(rng.random((n_txns, w)) < 0.3, -1,
                 rng.integers(0, n_locs, (n_txns, w))), jnp.int32)
    est = jnp.asarray(rng.random(n_txns) < 0.25)
    inc = jnp.asarray(rng.integers(0, 5, n_txns), jnp.int32)
    # queries include NO_LOC, out-of-universe, and snapshot readers
    locs = jnp.asarray(np.concatenate([
        rng.integers(0, n_locs, 150), [-1, -1, n_locs + 3],
        np.arange(n_locs)]), jnp.int32)
    readers = jnp.asarray(np.concatenate([
        rng.integers(0, n_txns + 1, 153),
        np.full(n_locs, n_txns)]), jnp.int32)

    single = mv.ShardedBackend.from_universe(n_txns, n_locs, n_shards)
    ref = jax.vmap(single.make_resolver(single.build(write_locs), write_locs,
                                        est, inc))(locs, readers)

    from repro.core.dist.backend import DistShardedBackend
    for d in (1, 2, 8):
        mesh = make_mesh("regions", (d,))
        cfg = EngineConfig(n_txns=n_txns, n_locs=n_locs, max_reads=4,
                           max_writes=w, backend="sharded",
                           n_shards=n_shards, dist=True, mesh=mesh)
        backend = DistShardedBackend.from_config(cfg)

        @functools.partial(shard_map, mesh=mesh, in_specs=(P(),) * 5,
                           out_specs=P(), check_rep=False)
        def routed(wl, e, i, ls, rs):
            return backend.resolve_batch(backend.build(wl), wl, e, i, ls, rs)

        got = routed(write_locs, est, inc, locs, readers)
        for field in ref._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, field)),
                np.asarray(getattr(ref, field)), err_msg=f"D={d} {field}")


# ---------------------------------------------------------------------------
# Engine equivalence: dist == single-device sharded, byte for byte
# ---------------------------------------------------------------------------

def _contended_spec(contention):
    if contention == "high":
        return W.MixedSpec(
            p2p=W.P2PSpec(n_accounts=8), indirect=W.IndirectSpec(n_slots=8),
            admission=W.AdmissionSpec(n_tenants=2, n_groups=4,
                                      total_pages=10**6,
                                      quota_per_tenant=10**6))
    return W.MixedSpec(
        p2p=W.P2PSpec(n_accounts=400), indirect=W.IndirectSpec(n_slots=200),
        admission=W.AdmissionSpec(n_tenants=16, n_groups=64,
                                  total_pages=10**6, quota_per_tenant=10**5))


@needs_mesh
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**16),
       contention=st.sampled_from(["high", "low"]),
       n_shards=st.sampled_from([1, 3, 16]))
def test_dist_matches_single_device_sharded(seed, contention, n_shards):
    """Same snapshot bytes, same stats, on 1/2/8-device meshes — including
    region counts (1, 3) that do not divide the device counts."""
    vm, params, storage, cfg = W.make_mixed_block(
        _contended_spec(contention), 32, seed=seed, window=8,
        backend="sharded", n_shards=n_shards)
    ref = run_block(vm, params, storage, cfg)
    assert bool(ref.committed)
    np.testing.assert_array_equal(
        np.asarray(ref.snapshot),
        run_sequential(vm, params, storage, 32))
    for d in (1, 2, 8):
        dcfg = dataclasses.replace(cfg, dist=True,
                                   mesh=make_mesh("regions", (d,)))
        res = run_block(vm, params, storage, dcfg)
        np.testing.assert_array_equal(np.asarray(res.snapshot),
                                      np.asarray(ref.snapshot),
                                      err_msg=f"D={d}")
        assert _stats(res) == _stats(ref), (d, _stats(res), _stats(ref))


@needs_mesh
def test_dist_engine_variants_match():
    """Every maintenance/validation regime stays exact on the mesh: rebuild
    reference, no-skip, windowed validation, and the cap-2 gather fallback
    all commit the single-device snapshot and stats."""
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), 24, seed=11, n_locs=50_000, zipf_s=1.1, window=8,
        backend="sharded", n_shards=6)
    mesh = make_mesh("regions", (2,))
    for variant in (dict(),
                    dict(mv_update="rebuild", dirty_validation=False),
                    dict(dirty_validation=False),
                    dict(validation_window=8),
                    dict(dirty_validation_cap=2)):
        c1 = dataclasses.replace(cfg, **variant)
        r1 = run_block(vm, params, storage, c1)
        rd = run_block(vm, params, storage,
                       dataclasses.replace(c1, dist=True, mesh=mesh))
        np.testing.assert_array_equal(np.asarray(rd.snapshot),
                                      np.asarray(r1.snapshot),
                                      err_msg=str(variant))
        assert _stats(rd) == _stats(r1), (variant, _stats(rd), _stats(r1))


@needs_mesh
def test_dist_zero_recompiles_across_mixes_on_fixed_mesh():
    """One jitted executor per mesh serves every contract mix."""
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(ratios=(1, 1, 1)), 32, seed=0, n_locs=20_000, window=8,
        backend="sharded", n_shards=6)
    dcfg = dataclasses.replace(cfg, dist=True,
                               mesh=make_mesh("regions", (8,)))
    run = make_executor(vm, dcfg)
    for i, ratios in enumerate([(1, 1, 1), (8, 1, 1), (1, 1, 8)]):
        _, params, storage, _ = W.make_mixed_block(
            W.MixedSpec(ratios=ratios), 32, seed=10 + i, n_locs=20_000,
            window=8, backend="sharded", n_shards=6)
        res = run(params, storage)
        assert bool(res.committed)
        np.testing.assert_array_equal(
            np.asarray(res.snapshot),
            run_sequential(vm, params, storage, 32))
    assert run._cache_size() == 1, run._cache_size()


# ---------------------------------------------------------------------------
# Execute-lane partition: windows that don't divide D, starved devices
# ---------------------------------------------------------------------------

@needs_mesh
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**16), window=st.sampled_from([5, 7]))
def test_dist_execute_partition_non_dividing_window(seed, window):
    """window % n_devices != 0: the lane partition pads to ceil(window/D)*D
    with fill lanes (id n) and the trailing pad is sliced off after the
    ExecResult all_gather — snapshot and stats stay byte-identical."""
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), 24, seed=seed, window=window, backend="sharded",
        n_shards=6)
    ref = run_block(vm, params, storage, cfg)
    assert bool(ref.committed)
    for d in (1, 2, 8):
        res = run_block(vm, params, storage,
                        dataclasses.replace(cfg, dist=True,
                                            mesh=make_mesh("regions", (d,))))
        np.testing.assert_array_equal(np.asarray(res.snapshot),
                                      np.asarray(ref.snapshot),
                                      err_msg=f"D={d} window={window}")
        assert _stats(res) == _stats(ref), (d, _stats(res), _stats(ref))


@needs_mesh
def test_dist_execute_partition_starved_devices():
    """A 6-txn block with window=8 on 8 devices gives every device ONE lane
    and leaves >= 2 devices holding only fill lanes (id n) on the very first
    wave — and most devices fill-only in later waves as the frontier drains.
    Fill lanes must execute as inert no-ops on their device: same snapshot,
    same stats, and exec-lane telemetry that sums to the live wave sizes."""
    vm, params, storage, cfg = W.make_mixed_block(
        _contended_spec("high"), 6, seed=2, window=8, backend="sharded",
        n_shards=4, trace_level=1)
    ref = run_block(vm, params, storage, cfg)
    assert bool(ref.committed)
    for d in (1, 2, 8):
        res = run_block(vm, params, storage,
                        dataclasses.replace(cfg, dist=True,
                                            mesh=make_mesh("regions", (d,))))
        np.testing.assert_array_equal(np.asarray(res.snapshot),
                                      np.asarray(ref.snapshot),
                                      err_msg=f"D={d}")
        assert _stats(res) == _stats(ref), (d, _stats(res), _stats(ref))
        # the per-device exec-lane counters partition each wave exactly
        waves = int(res.waves)
        lanes = np.asarray(res.trace.exec_lanes)  # (D, cap)
        assert lanes.shape[0] == d
        np.testing.assert_array_equal(
            lanes[:, :waves].sum(axis=0),
            np.asarray(ref.trace.exec_lanes)[:waves], err_msg=f"D={d}")


# ---------------------------------------------------------------------------
# Chains: run_chain's scan composes with the dist engine's collectives
# ---------------------------------------------------------------------------

@needs_mesh
def test_dist_chain_matches_single_device():
    """A 3-block chain scanned through run_block_dist: every block's
    snapshot feeds the next, byte-identical to the single-device chain on
    1/2/8-device meshes, traced and untraced, eager and jitted."""
    from repro.core.engine import run_chain
    n_txns, n_blocks = 16, 3
    vm, params0, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), n_txns, seed=7, window=8, backend="sharded",
        n_shards=4)
    blocks = []
    for b in range(n_blocks):
        _, p, _, _ = W.make_mixed_block(W.MixedSpec(), n_txns, seed=40 + b,
                                        window=8, backend="sharded",
                                        n_shards=4)
        blocks.append(p)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)

    final_ref, stats_ref = run_chain(vm, stacked, storage, cfg)
    assert bool(np.asarray(stats_ref.committed).all())
    # trace_level=1 exercises the merge-collective-inside-scan composition
    # on one mesh; the untraced chain runs on every mesh size
    for d, tl in ((1, 0), (2, 0), (2, 1), (8, 0)):
        dcfg = dataclasses.replace(cfg, dist=True, trace_level=tl,
                                   mesh=make_mesh("regions", (d,)))
        final_d, stats_d = jax.jit(
            lambda bp, st, c=dcfg: run_chain(vm, bp, st, c))(stacked,
                                                             storage)
        np.testing.assert_array_equal(np.asarray(final_d),
                                      np.asarray(final_ref),
                                      err_msg=f"D={d} tl={tl}")
        for f in STATS:
            np.testing.assert_array_equal(
                np.asarray(getattr(stats_d, f)),
                np.asarray(getattr(stats_ref, f)),
                err_msg=f"D={d} tl={tl} {f}")


@needs_mesh
def test_dist_10m_locations_zipf_matches_sequential():
    """The acceptance block at scale: a 10M-location Zipfian universe
    (beyond the flat int32 key bound) executed ACROSS THE MESH, with the
    snapshot sliced per device and gathered, byte-identical to the
    sequential oracle — and to the single-device sharded engine's stats."""
    n_txns, n_locs = 256, 10_000_000
    assert n_locs * (n_txns + 1) + n_txns >= 2**31
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), n_txns, seed=5, n_locs=n_locs, zipf_s=1.1,
        window=32, backend="sharded", n_shards=16)
    snap, committed, _ = run_engine("blockstm", vm, params, storage, cfg,
                                    mesh=make_mesh("regions", (8,)))
    assert bool(committed)
    np.testing.assert_array_equal(
        np.asarray(snap), run_sequential(vm, params, storage, n_txns))

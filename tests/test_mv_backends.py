"""MV backend suite: protocol conformance, equivalence, and the int32 bound.

* Backend equivalence — ``sorted``, ``dense``, and ``sharded`` (at shard
  counts that do and do not divide ``n_locs``) must commit byte-identical
  snapshots AND identical abort/wave statistics on random mixed blocks:
  resolution-for-resolution agreement, not just final-state agreement.
* The int32 key bound — ``EngineConfig`` rejects flat-backend universes whose
  keys ``loc*(n_txns+1)+writer`` overflow, naming the offending sizes and the
  sharded backend as the fix; ``sharded`` accepts the same universe.
* Million-location universes — a 10M-location mixed bytecode block (beyond
  the flat int32 key bound) executes under ``backend='sharded'`` to a
  snapshot byte-identical with ``run_sequential``, with zero recompiles
  across contract mixes and shard counts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import mv
from repro.core import workloads as W
from repro.core.engine import make_executor, run_block
from repro.core.mv.sharded import row_searchsorted, shard_plan
from repro.core.types import EngineConfig
from repro.core.vm import run_sequential

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# EngineConfig int32 key-bound validation (satellite bugfix)
# ---------------------------------------------------------------------------

def _cfg(n_txns, n_locs, **kw):
    return EngineConfig(n_txns=n_txns, n_locs=n_locs, max_reads=4,
                        max_writes=4, **kw)


def test_config_rejects_flat_int32_overflow():
    n_txns, n_locs = 1024, 3_000_000        # 3e6 * 1025 >= 2^31
    with pytest.raises(ValueError) as exc:
        _cfg(n_txns, n_locs)
    msg = str(exc.value)
    assert str(n_locs) in msg and str(n_txns) in msg and "sharded" in msg
    with pytest.raises(ValueError):
        _cfg(n_txns, n_locs, backend="dense")   # dense keys the same universe
    # the named fix works: the identical universe under the sharded backend
    cfg = _cfg(n_txns, n_locs, backend="sharded")
    assert cfg.backend == "sharded"


def test_config_rejects_undersized_explicit_shards():
    with pytest.raises(ValueError, match="n_shards"):
        _cfg(1024, 10_000_000, backend="sharded", n_shards=1)
    # auto (n_shards=0) picks a workable count for the same universe
    _cfg(1024, 10_000_000, backend="sharded")


def test_config_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        _cfg(8, 64, backend="hashmap")


def test_shard_plan_bounds():
    n_shards, shard_size = shard_plan(10_000_000, 1024, n_shards=0)
    assert shard_size * 1025 + 1024 < 2**31
    assert n_shards * shard_size >= 10_000_000
    # never more shards than locations: 10 locs over 16 shards -> 10 shards
    assert shard_plan(10, 4, n_shards=16) == (10, 1)
    # non-dividing counts round the tail shard down, never out of range
    n_shards, shard_size = shard_plan(43, 64, n_shards=16)
    assert (n_shards - 1) * shard_size < 43 <= n_shards * shard_size


# ---------------------------------------------------------------------------
# Sharded index internals
# ---------------------------------------------------------------------------

def test_row_searchsorted_matches_numpy():
    rng = np.random.default_rng(0)
    for cap in (1, 2, 7, 32):
        keys = np.sort(rng.integers(0, 50, (5, cap)), axis=1).astype(np.int32)
        rows = rng.integers(0, 5, 64).astype(np.int32)
        qs = rng.integers(-5, 55, 64).astype(np.int32)
        got = jax.vmap(lambda r, q: row_searchsorted(jnp.asarray(keys), r, q))(
            jnp.asarray(rows), jnp.asarray(qs))
        exp = [np.searchsorted(keys[r], q, side="left")
               for r, q in zip(rows, qs)]
        np.testing.assert_array_equal(np.asarray(got), exp)


def test_sharded_build_partitions_all_live_slots():
    cfg = _cfg(4, 20, backend="sharded", n_shards=4)
    backend = mv.make_backend(cfg)
    write_locs = jnp.asarray([[0, 19], [5, -1], [5, 12], [-1, -1]], jnp.int32)
    index = backend.build(write_locs)
    assert index.keys.shape == (8,)                      # CSR-flat: n*W
    keys, starts = np.asarray(index.keys), np.asarray(index.starts)
    assert starts[0] == 0 and starts[-1] == 5            # live slots only
    assert (keys[starts[-1]:] == np.iinfo(np.int32).max).all()  # dead tail
    assert (np.asarray(index.packed)[starts[-1]:] == 0).all()   # normalized
    # every region segment sorted ascending
    for s in range(backend.n_shards):
        seg = keys[starts[s]:starts[s + 1]]
        assert (np.diff(seg) >= 0).all()
    # shard_size 5: loc 0 -> s0; 5, 5 -> s1; 12 -> s2; 19 -> s3
    np.testing.assert_array_equal(starts, [0, 1, 3, 4, 5])
    resolver = backend.make_resolver(index, write_locs,
                                     jnp.zeros((4,), jnp.bool_),
                                     jnp.zeros((4,), jnp.int32))
    res = resolver(jnp.asarray(5, jnp.int32), jnp.asarray(4, jnp.int32))
    assert bool(res.found) and int(res.writer) == 2      # highest writer wins
    res = resolver(jnp.asarray(5, jnp.int32), jnp.asarray(1, jnp.int32))
    assert not bool(res.found)                           # no lower writer
    res = resolver(jnp.asarray(-1, jnp.int32), jnp.asarray(4, jnp.int32))
    assert not bool(res.found)                           # NO_LOC never found


# ---------------------------------------------------------------------------
# Backend equivalence: byte-identical snapshots AND identical statistics
# ---------------------------------------------------------------------------

def _contended_spec(contention):
    if contention == "high":
        return W.MixedSpec(
            p2p=W.P2PSpec(n_accounts=8), indirect=W.IndirectSpec(n_slots=8),
            admission=W.AdmissionSpec(n_tenants=2, n_groups=4,
                                      total_pages=10**6,
                                      quota_per_tenant=10**6))
    return W.MixedSpec(
        p2p=W.P2PSpec(n_accounts=400), indirect=W.IndirectSpec(n_slots=200),
        admission=W.AdmissionSpec(n_tenants=16, n_groups=64,
                                  total_pages=10**6, quota_per_tenant=10**5))


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**16),
       contention=st.sampled_from(["high", "low"]),
       window=st.sampled_from([4, 16]))
def test_backend_equivalence_on_mixed_blocks(seed, contention, window):
    """sorted ≡ dense ≡ sharded{1,4,16}: same snapshot bytes, same stats."""
    import dataclasses
    vm, params, storage, cfg = W.make_mixed_block(
        _contended_spec(contention), 32, seed=seed, window=window)
    expected = run_sequential(vm, params, storage, 32)
    stats = {}
    variants = [("sorted", 0), ("dense", 0), ("sharded", 1), ("sharded", 4),
                ("sharded", 16)]   # 16 does not divide either universe size
    for backend, n_shards in variants:
        c = dataclasses.replace(cfg, backend=backend, n_shards=n_shards)
        res = run_block(vm, params, storage, c)
        assert bool(res.committed), (backend, n_shards)
        np.testing.assert_array_equal(np.asarray(res.snapshot), expected,
                                      err_msg=f"{backend}/{n_shards}")
        stats[(backend, n_shards)] = (int(res.waves), int(res.execs),
                                      int(res.dep_aborts),
                                      int(res.val_aborts),
                                      int(res.wrote_new))
    assert len(set(stats.values())) == 1, stats


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**16), zipf_s=st.sampled_from([0.0, 0.8, 1.1]))
def test_sharded_zipf_blocks_match_sequential(seed, zipf_s):
    """Zipf-contended blocks: skew drives conflicts, sharding stays exact."""
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), 24, seed=seed, n_locs=50_000, zipf_s=zipf_s,
        window=8, backend="sharded", n_shards=4)
    res = run_block(vm, params, storage, cfg)
    assert bool(res.committed)
    expected = run_sequential(vm, params, storage, 24)
    np.testing.assert_array_equal(np.asarray(res.snapshot), expected)


# ---------------------------------------------------------------------------
# Million-location universes (beyond the flat int32 key bound)
# ---------------------------------------------------------------------------

def test_sharded_10m_locations_matches_sequential():
    """The acceptance block: a 10M-location universe BEYOND the flat int32
    key bound (1e7*(256+1) ≈ 2.57e9 > 2^31 — the sorted/dense backends
    refuse this config outright), executed by the sharded backend to a
    snapshot byte-identical with the sequential oracle."""
    n_txns, n_locs = 256, 10_000_000
    assert n_locs * (n_txns + 1) + n_txns >= 2**31
    with pytest.raises(ValueError, match="sharded"):
        _cfg(n_txns, n_locs, backend="sorted")
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), n_txns, seed=5, n_locs=n_locs, zipf_s=1.1,
        window=32, backend="sharded", n_shards=16)
    run = make_executor(vm, cfg)
    res = run(params, storage)
    assert bool(res.committed)
    expected = run_sequential(vm, params, storage, n_txns)
    np.testing.assert_array_equal(np.asarray(res.snapshot), expected)


def test_sharded_zero_recompiles_across_mixes_and_shard_counts():
    """Per shard count, ONE jitted executor serves every contract mix."""
    n_txns, n_locs = 32, 20_000
    for n_shards in (1, 4, 16):
        vm, params, storage, cfg = W.make_mixed_block(
            W.MixedSpec(ratios=(1, 1, 1)), n_txns, seed=0, n_locs=n_locs,
            window=8, backend="sharded", n_shards=n_shards)
        run = make_executor(vm, cfg)
        for i, ratios in enumerate([(1, 1, 1), (8, 1, 1), (1, 1, 8)]):
            _, params, storage, _ = W.make_mixed_block(
                W.MixedSpec(ratios=ratios), n_txns, seed=10 + i,
                n_locs=n_locs, window=8, backend="sharded",
                n_shards=n_shards)
            res = run(params, storage)
            assert bool(res.committed)
            expected = run_sequential(vm, params, storage, n_txns)
            np.testing.assert_array_equal(np.asarray(res.snapshot), expected)
        assert run._cache_size() == 1, \
            f"n_shards={n_shards}: expected one compile, " \
            f"cache has {run._cache_size()}"


# ---------------------------------------------------------------------------
# Zipf sampler (workload layer)
# ---------------------------------------------------------------------------

def test_zipf_choice_uniform_path_is_bit_identical():
    a = W.zipf_choice(np.random.default_rng(3), 1000, 512, 0.0)
    b = np.random.default_rng(3).integers(0, 1000, 512)
    np.testing.assert_array_equal(a, b)


def test_zipf_choice_skews_toward_low_ids():
    rng = np.random.default_rng(0)
    draws = W.zipf_choice(rng, 1_000_000, 20_000, 1.1)
    assert draws.min() >= 0 and draws.max() < 1_000_000
    # heavy head: a tiny id prefix absorbs a large share of the mass
    head_share = (draws < 100).mean()
    assert head_share > 0.3, head_share
    uniform_head = (W.zipf_choice(rng, 1_000_000, 20_000, 0.0) < 100).mean()
    assert head_share > 10 * max(uniform_head, 1e-4)


def test_make_p2p_block_zipf_keeps_distinct_endpoints():
    params, _ = W.make_p2p_block(W.P2PSpec(n_accounts=50), 256, seed=1,
                                 zipf_s=1.2)
    src, dst = np.asarray(params["src"]), np.asarray(params["dst"])
    assert (src != dst).all()

"""End-to-end system tests: the serving path (Block-STM admission + decode),
the training driver loop, and engine statistics matching the paper's
contention narrative."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import workloads as W
from repro.core.engine import run_block
from repro.core.vm import run_sequential

jax.config.update("jax_platform_name", "cpu")


def test_contention_narrative():
    """Paper Fig. 4/7: abort rate falls as the account set grows; the
    fully-sequential 2-account workload commits ~1 txn/wave; low-contention
    commits nearly all txns in few waves."""
    stats = {}
    for acc in (2, 10, 100, 1000):
        spec = W.P2PSpec(n_accounts=acc)
        params, storage = W.make_p2p_block(spec, 96, seed=1)
        cfg = W.p2p_engine_config(spec, 96, window=16)
        res = run_block(W.p2p_program(spec), params, storage, cfg)
        assert bool(res.committed)
        stats[acc] = dict(waves=int(res.waves), execs=int(res.execs),
                          val_aborts=int(res.val_aborts))
    # speculative re-execution overhead decreases with the account count
    # (acc=2 is excluded from the monotone chain: the fully-sequential chain
    # mostly *dependency*-aborts — cheap, not counted as executions)
    assert stats[10]["execs"] >= stats[100]["execs"] >= stats[1000]["execs"]
    # low contention: near-one incarnation per txn
    assert stats[1000]["execs"] <= 96 * 1.2
    # sequential: bounded overhead (paper: <=30% wall overhead; here:
    # bounded incarnations)
    assert stats[2]["execs"] <= 96 * 2.6


def test_serving_round_end_to_end():
    """Admission block -> page accounting -> decode steps, all consistent."""
    from repro.configs import get_arch, reduced_config
    from repro.models import model as MDL

    spec = W.AdmissionSpec(n_tenants=4, n_groups=16, total_pages=64,
                           quota_per_tenant=32)
    reqs, storage = W.make_admission_block(spec, 32, seed=0)
    cfg = W.admission_engine_config(spec, 32, window=8)
    res = run_block(W.admission_program(spec), reqs, storage, cfg)
    assert bool(res.committed)
    snap = np.asarray(res.snapshot)
    exp = run_sequential(W.admission_program(spec), reqs, storage, 32)
    np.testing.assert_array_equal(snap, exp)
    # invariant: allocated pages == sum of tenant usage == sum of group pages
    assert snap[0] == snap[1:1 + spec.n_tenants].sum()
    assert snap[0] == snap[1 + spec.n_tenants:].sum()
    assert snap[0] <= spec.total_pages

    # decode a few tokens on the admitted batch
    mcfg = reduced_config(get_arch("gemma-2b"))
    params = MDL.init_params(jax.random.PRNGKey(0), mcfg, jnp.float32)
    cache = MDL.init_cache(mcfg, batch=4, max_seq=8, dtype=jnp.float32)
    toks = jnp.zeros((4,), jnp.int32)
    step = jax.jit(lambda p, c, t: MDL.decode_step(p, c, t, mcfg))
    for _ in range(4):
        logits, cache = step(params, cache, toks)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert np.isfinite(np.asarray(logits)).all()


def test_train_driver_cli(tmp_path):
    """The training launcher runs end-to-end (reduced config) and resumes."""
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "gemma-2b",
           "--reduced", "--steps", "6", "--batch", "2", "--seq", "16",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
           "--log-every", "2"]
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    r1 = subprocess.run(cmd, capture_output=True, text=True, env=env,
                        cwd="/root/repo", timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert "done:" in r1.stdout
    # resume: should restore from step 6 and exit immediately
    r2 = subprocess.run(cmd, capture_output=True, text=True, env=env,
                        cwd="/root/repo", timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[restore] resumed from step 6" in r2.stdout

"""Baseline engines (paper §4.1 comparisons): Bohm (perfect write sets) and
LiTM-style deterministic STM — correctness + behavioral properties.

Every test runs through the unified executor protocol
(``repro.core.executor.run_engine``) and is parametrized over BOTH program
substrates: the traced Python DSL and the bytecode VM (``compile_p2p`` +
``BytecodeVM``), which exercise the protocol's two dispatch arms.
"""
import jax
import numpy as np
from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro.bytecode import compile as BC
from repro.core import baselines as B
from repro.core import workloads as W
from repro.core.executor import run_engine

jax.config.update("jax_platform_name", "cpu")

SUBSTRATES = ("dsl", "bytecode")


def _block(substrate, acc, n, seed):
    """(program, params, storage, cfg) for one p2p block on either substrate."""
    spec = W.P2PSpec(n_accounts=acc)
    params, storage = W.make_p2p_block(spec, n, seed=seed)
    if substrate == "dsl":
        return W.p2p_program(spec), params, storage, W.p2p_engine_config(spec, n)
    prog = BC.compile_p2p(spec)
    args = BC.pack_args({k: np.asarray(v) for k, v in params.items()},
                        BC.P2P_ARGS, prog.n_params)
    vm, cfg = BC.vm_and_config([prog], n, spec.n_locs)
    return vm, BC.homogeneous_block_params(prog, args), storage, cfg


@settings(max_examples=10, deadline=None)
@given(substrate=st.sampled_from(SUBSTRATES),
       acc=st.sampled_from([2, 10, 100]), n=st.integers(4, 40),
       seed=st.integers(0, 1000))
def test_bohm_equivalence(substrate, acc, n, seed):
    program, params, storage, cfg = _block(substrate, acc, n, seed)
    exp, _, _ = run_engine("sequential", program, params, storage, cfg)
    snap, committed, stats = run_engine("bohm", program, params, storage, cfg)
    assert bool(committed), substrate
    np.testing.assert_array_equal(np.asarray(snap), np.asarray(exp))
    # perfect write sets => every txn executes exactly once
    assert int(stats["execs"]) == n


@settings(max_examples=10, deadline=None)
@given(substrate=st.sampled_from(SUBSTRATES),
       acc=st.sampled_from([2, 10, 100]), n=st.integers(4, 40),
       seed=st.integers(0, 1000))
def test_litm_equivalence(substrate, acc, n, seed):
    program, params, storage, cfg = _block(substrate, acc, n, seed)
    snap, committed, _ = run_engine("litm", program, params, storage, cfg)
    assert bool(committed), substrate
    exp, _, _ = run_engine("sequential", program, params, storage, cfg)
    np.testing.assert_array_equal(np.asarray(snap), np.asarray(exp))


@settings(max_examples=4, deadline=None)
@given(substrate=st.sampled_from(SUBSTRATES), seed=st.integers(0, 100))
def test_litm_degrades_under_contention_vs_bohm(substrate, seed):
    """The paper's qualitative contrast: LiTM re-executes heavily under
    contention; Bohm never wastes an execution.  Holds on both substrates."""
    program, params, storage, cfg = _block(substrate, 2, 48, seed)
    _, bohm_ok, bohm_stats = run_engine("bohm", program, params, storage, cfg)
    _, litm_ok, litm_stats = run_engine("litm", program, params, storage, cfg)
    assert bool(bohm_ok) and bool(litm_ok)
    assert int(bohm_stats["execs"]) == 48
    assert int(litm_stats["execs"]) > 5 * 48     # quadratic re-execution blowup


def test_perfect_write_sets_both_substrates_agree():
    """The oracle pre-pass sees through both program representations."""
    for substrate in SUBSTRATES:
        program, params, storage, cfg = _block(substrate, 10, 12, seed=5)
        pws = np.asarray(B.perfect_write_sets(program, params, storage, cfg))
        if substrate == "dsl":
            ref = pws
    # identical blocks => identical true write sets, up to slot padding
    np.testing.assert_array_equal(np.sort(ref, axis=1), np.sort(pws, axis=1))

"""Baseline engines (paper §4.1 comparisons): Bohm (perfect write sets) and
LiTM-style deterministic STM — correctness + behavioral properties."""
import jax
import numpy as np
from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import baselines as B
from repro.core import workloads as W
from repro.core.vm import run_sequential

jax.config.update("jax_platform_name", "cpu")


def _block(acc, n, seed):
    spec = W.P2PSpec(n_accounts=acc)
    params, storage = W.make_p2p_block(spec, n, seed=seed)
    cfg = W.p2p_engine_config(spec, n)
    return spec, params, storage, cfg


@settings(max_examples=10, deadline=None)
@given(acc=st.sampled_from([2, 10, 100]), n=st.integers(4, 40),
       seed=st.integers(0, 1000))
def test_bohm_equivalence(acc, n, seed):
    spec, params, storage, cfg = _block(acc, n, seed)
    pws = B.perfect_write_sets(W.p2p_program(spec), params, storage, cfg)
    r = B.run_bohm(W.p2p_program(spec), params, storage, cfg, pws)
    assert bool(r.committed)
    exp = run_sequential(W.p2p_program(spec), params, storage, n)
    np.testing.assert_array_equal(np.asarray(r.snapshot), exp)
    # perfect write sets => every txn executes exactly once
    assert int(r.execs) == n


@settings(max_examples=10, deadline=None)
@given(acc=st.sampled_from([2, 10, 100]), n=st.integers(4, 40),
       seed=st.integers(0, 1000))
def test_litm_equivalence(acc, n, seed):
    spec, params, storage, cfg = _block(acc, n, seed)
    r = B.run_litm(W.p2p_program(spec), params, storage, cfg)
    assert bool(r.committed)
    exp = run_sequential(W.p2p_program(spec), params, storage, n)
    np.testing.assert_array_equal(np.asarray(r.snapshot), exp)


def test_litm_degrades_under_contention_vs_bohm():
    """The paper's qualitative contrast: LiTM re-executes heavily under
    contention; Bohm never wastes an execution."""
    spec, params, storage, cfg = _block(2, 48, seed=1)
    pws = B.perfect_write_sets(W.p2p_program(spec), params, storage, cfg)
    rb = B.run_bohm(W.p2p_program(spec), params, storage, cfg, pws)
    rl = B.run_litm(W.p2p_program(spec), params, storage, cfg)
    assert int(rb.execs) == 48
    assert int(rl.execs) > 5 * 48     # quadratic re-execution blowup

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as fa_k, ref as fa_r
from repro.kernels.mv_resolve import kernel as mv_k, ops as mv_o, ref as mv_r
from repro.kernels.selective_scan import kernel as ss_k, ref as ss_r

jax.config.update("jax_platform_name", "cpu")
RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# mv_resolve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 1), (7, 5), (64, 64), (300, 130),
                                   (513, 257)])
@pytest.mark.parametrize("dtype", [np.int32])
def test_mv_resolve_shapes(shape, dtype):
    n, l = shape
    marks = RNG.integers(-1, max(n, 2), shape).astype(dtype)
    got = mv_k.mv_resolve_inclusive(jnp.asarray(marks), block_n=64,
                                    block_l=128)
    want = mv_r.mv_resolve_inclusive_ref(jnp.asarray(marks))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("blocks", [(8, 16), (32, 32), (256, 512)])
def test_mv_resolve_block_sweep(blocks):
    bn, bl = blocks
    marks = RNG.integers(-1, 100, (100, 96)).astype(np.int32)
    got = mv_k.mv_resolve_inclusive(jnp.asarray(marks), block_n=bn,
                                    block_l=bl)
    want = mv_r.mv_resolve_inclusive_ref(jnp.asarray(marks))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mv_resolve_exclusive_wrapper():
    marks = RNG.integers(-1, 50, (50, 33)).astype(np.int32)
    got = mv_o.exclusive_cummax(jnp.asarray(marks))
    want = mv_r.exclusive_cummax_ref(jnp.asarray(marks))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

CASES = [
    # (B, Hq, Hkv, Sq, Skv, D, causal)
    (1, 2, 2, 16, 16, 8, True),
    (2, 4, 2, 64, 64, 32, True),        # GQA
    (1, 8, 1, 33, 33, 16, True),        # MQA + ragged seq
    (2, 4, 4, 1, 40, 16, True),         # decode: q_len=1 vs cache
    (1, 2, 2, 24, 24, 8, False),        # bidirectional (encoder)
    (1, 4, 2, 48, 96, 64, True),        # cross-length causal w/ offset
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(case, dtype):
    b, hq, hkv, sq, skv, d, causal = case
    q = jnp.asarray(RNG.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, skv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, skv, d)), dtype)
    got = fa_k.flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    want = fa_r.attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("chunk", [8, 32, 1024])
def test_chunked_attention_matches_naive(chunk):
    q = jnp.asarray(RNG.standard_normal((2, 4, 64, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2, 64, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2, 64, 16)), jnp.float32)
    got = fa_r.attention_chunked_ref(q, k, v, chunk=chunk)
    want = fa_r.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_chunked_attention_grad_finite():
    q = jnp.asarray(RNG.standard_normal((1, 2, 32, 8)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 32, 8)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 32, 8)), jnp.float32)
    g = jax.grad(lambda q_: jnp.sum(
        fa_r.attention_chunked_ref(q_, k, v, chunk=8) ** 2))(q)
    assert np.isfinite(np.asarray(g)).all()


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

SCAN_CASES = [(1, 8, 4, 2), (2, 33, 16, 4), (1, 64, 24, 16), (2, 17, 7, 3)]


@pytest.mark.parametrize("case", SCAN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_selective_scan(case, dtype):
    b, t, d, s = case
    x = jnp.asarray(RNG.standard_normal((b, t, d)), dtype)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, t, d))) * 0.1, dtype)
    a = jnp.asarray(-np.abs(RNG.standard_normal((d, s))), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b, t, s)), dtype)
    cc = jnp.asarray(RNG.standard_normal((b, t, s)), dtype)
    got = ss_k.selective_scan(x, dt, a, bb, cc, block_t=16, block_d=8)
    want = ss_r.selective_scan_seq_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("chunk", [4, 16, 128])
def test_selective_scan_chunked(chunk):
    b, t, d, s = 2, 50, 8, 4
    x = jnp.asarray(RNG.standard_normal((b, t, d)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, t, d))) * 0.1, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.standard_normal((d, s))), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b, t, s)), jnp.float32)
    cc = jnp.asarray(RNG.standard_normal((b, t, s)), jnp.float32)
    got = ss_r.selective_scan_chunked(x, dt, a, bb, cc, chunk=chunk)
    want = ss_r.selective_scan_seq_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_ssd_chunked_vs_stepwise():
    """Mamba-2 SSD chunked form vs literal per-step recurrence."""
    from repro.models.mamba import ssd_chunked
    b, l, h, p, n = 2, 24, 3, 4, 5
    x = jnp.asarray(RNG.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, l, h))) * 0.1, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.standard_normal((h,))), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
    cc = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
    got, final = ssd_chunked(x, dt, a, bb, cc, chunk=8)

    # stepwise reference
    hstate = np.zeros((b, h, n, p), np.float32)
    ys = []
    for t in range(l):
        decay = np.exp(np.asarray(dt)[:, t] * np.asarray(a)[None])  # (b,h)
        upd = np.einsum("bh,bn,bhp->bhnp", np.asarray(dt)[:, t],
                        np.asarray(bb)[:, t], np.asarray(x)[:, t])
        hstate = decay[:, :, None, None] * hstate + upd
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(cc)[:, t], hstate))
    want = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), hstate, atol=2e-4)

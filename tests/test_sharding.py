"""Distribution machinery tests on a small host mesh (no 512-dev requirement):
spec resolution, sanitized shardings, HLO cost walker, drylib roofline math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import meshctx
from repro.launch import hlo_analysis as H
from repro.launch.drylib import CellResult, model_flops
from repro.configs import SHAPES_BY_NAME, get_arch

jax.config.update("jax_platform_name", "cpu")


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_spec_logical_axes():
    mesh = _mesh()
    spec = meshctx.resolve_spec((meshctx.BATCH, None, meshctx.MODEL), mesh)
    assert spec == P(("data",), None, "model")


def test_is_spec_rejects_namedtuples():
    from repro.runtime.steps import TrainState
    assert meshctx.is_spec((None, "model"))
    assert meshctx.is_spec(())
    assert not meshctx.is_spec(TrainState(params=1, opt=2, step=3))


def test_constrain_skips_indivisible_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with meshctx.use_mesh(mesh):
        x = jnp.ones((3, 5))
        y = meshctx.constrain(x, meshctx.BATCH, meshctx.MODEL)  # 1-sized axes
        assert y.shape == x.shape


def test_tree_shardings_for_sanitizes_batch_of_one():
    mesh = _mesh()
    struct = jax.ShapeDtypeStruct((1, 8), jnp.float32)
    s = meshctx.tree_shardings_for((meshctx.BATCH, None), struct, mesh)
    assert isinstance(s, NamedSharding)


# ---------------------------------------------------------------------------
# HLO walker
# ---------------------------------------------------------------------------

def test_walker_counts_scan_trips():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    t = H.aggregate(c.as_text())
    exp = 10 * 2 * 64 ** 3
    assert abs(t["flops"] - exp) / exp < 0.05


def test_walker_counts_nested_scan_trips():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                         jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    t = H.aggregate(c.as_text())
    exp = 15 * 2 * 32 ** 3
    assert abs(t["flops"] - exp) / exp < 0.05


def test_walker_flops_match_cost_analysis_without_loops():
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jax.ShapeDtypeStruct((128, 256), jnp.float32),
                jax.ShapeDtypeStruct((256, 64), jnp.float32)).compile()
    t = H.aggregate(c.as_text())
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax returns a one-element list
        ca = ca[0]
    assert abs(t["flops"] - ca["flops"]) / ca["flops"] < 0.05


def test_walker_collectives_on_sharded_matmul():
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("model",))
    s = NamedSharding(mesh, P(None, "model"))
    f = jax.jit(lambda a, b: a @ b, in_shardings=(s, None),
                out_shardings=NamedSharding(mesh, P()))
    c = f.lower(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    t = H.aggregate(c.as_text())   # 1-dev mesh: no collectives, just sanity
    assert t["flops"] > 0


# ---------------------------------------------------------------------------
# roofline math
# ---------------------------------------------------------------------------

def test_roofline_terms_and_bound():
    r = CellResult(arch="a", shape="train_4k", mesh="m", status="ok",
                   n_devices=256, flops_dev=197e12, bytes_dev=819e9 * 2,
                   collectives={"collective_bytes": 50e9 * 0.5},
                   model_flops=197e12 * 256 * 0.5)
    rf = r.roofline()
    assert rf["compute_s"] == pytest.approx(1.0)
    assert rf["memory_s"] == pytest.approx(2.0)
    assert rf["collective_s"] == pytest.approx(0.5)
    assert rf["bound"] == "memory"
    assert rf["roofline_fraction"] == pytest.approx(0.25)


def test_model_flops_train_vs_decode():
    cfg = get_arch("gemma-2b")
    tr = model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    dec = model_flops(cfg, SHAPES_BY_NAME["decode_32k"])
    assert tr == pytest.approx(6 * cfg.active_param_count() * 256 * 4096)
    assert dec == pytest.approx(2 * cfg.active_param_count() * 128)

"""``hypothesis`` when installed, else a deterministic sampled fallback.

The property tests are the repo's central safety net (engine ≡ sequential),
so they must run even on images without hypothesis.  The fallback implements
just the surface these tests use — ``given``/``settings`` and the
``sampled_from``/``integers``/``floats`` strategies — drawing ``max_examples``
pseudo-random samples from a per-test deterministic seed.  No shrinking, no
database; with real hypothesis installed this module is a pass-through.

``REPRO_FAST_EXAMPLES=<k>`` caps ``max_examples`` at ``k`` in both modes —
the ``make test-fast`` tier-1 subset (deterministic, no hypothesis search).
"""
from __future__ import annotations

import os

_FAST_CAP = int(os.environ.get("REPRO_FAST_EXAMPLES", "0") or "0")

try:
    from hypothesis import given, settings as _hyp_settings, strategies as st
    HAVE_HYPOTHESIS = True

    if _FAST_CAP > 0:
        def settings(max_examples: int = 10, **kw):
            return _hyp_settings(
                max_examples=min(max_examples, _FAST_CAP), **kw)
    else:
        settings = _hyp_settings
except ImportError:
    HAVE_HYPOTHESIS = False

    import random
    import zlib

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def draw(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.randrange(2)))

    st = _Strategies()

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            # NOTE: the wrapper must take NO parameters — functools.wraps
            # would copy fn's signature and pytest would then demand fixtures
            # named after the strategy arguments.
            def wrapper():
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 10))
                if _FAST_CAP > 0:
                    n = min(n, _FAST_CAP)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(**drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

"""Incremental MV maintenance + dirty-region validation: exactness suite.

* ``update`` chains — random multi-wave write-set sequences applied through
  ``backend.update`` must stay byte-identical to a fresh ``build`` of the
  running write-loc matrix (index pytree AND resolutions) across
  sorted / dense / sharded@{1, 4, 16} — including non-dividing shard counts,
  re-executions that keep/shrink/move write sets, and empty waves.
* Dirty-region soundness — rows of regions NOT reported dirty are exact
  byte-carries of the previous index.
* Engine equivalence — ``mv_update='incremental'`` + ``dirty_validation``
  commits identical snapshots, frontier (committed), and abort/wave/exec
  statistics to the ``mv_update='rebuild'`` + ``validation_window=0`` full
  validation reference, on contended mixed blocks (the validation skip is a
  semantics-preserving optimization, not an approximation).
* Region-resolve kernel — interpret-mode parity against
  ``segment_searchsorted`` on indexes produced by the engine's own shard
  grid, and
  ``resolver_impl='pallas'`` selectable from ``EngineConfig`` with zero
  recompiles across contract mixes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import mv
from repro.core import workloads as W
from repro.core.engine import make_executor, run_block
from repro.core.types import NO_LOC, EngineConfig
from repro.core.vm import run_sequential

jax.config.update("jax_platform_name", "cpu")


def _cfg(n_txns, n_locs, **kw):
    return EngineConfig(n_txns=n_txns, n_locs=n_locs, max_reads=4,
                        max_writes=4, **kw)


def _backends(n_txns, n_locs):
    yield mv.SortedBackend(n_txns=n_txns)
    yield mv.DenseBackend(n_txns=n_txns, n_locs=n_locs)
    for n_shards in (1, 4, 16):       # 16 rarely divides the universe sizes
        yield mv.ShardedBackend.from_universe(n_txns, n_locs, n_shards)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), n_locs=st.sampled_from([7, 50, 1000]))
def test_update_chain_matches_build(seed, n_locs):
    """update∘update∘... ≡ build, byte for byte, for every backend."""
    rng = np.random.default_rng(seed)
    n, w, window, waves = 24, 3, 8, 7
    for backend in _backends(n, n_locs):
        wl = np.full((n, w), NO_LOC, np.int32)
        index = backend.build(jnp.asarray(wl))
        versions = np.zeros((backend.n_regions,), np.int64)
        for _ in range(waves):
            ids = np.unique(rng.choice(n, size=rng.integers(0, window + 1)))
            txn_ids = np.full((window,), n, np.int32)
            txn_ids[:len(ids)] = ids
            new = np.where(rng.random((window, w)) < 0.6,
                           rng.integers(0, n_locs, (window, w)),
                           NO_LOC).astype(np.int32)
            new[len(ids):] = NO_LOC
            old = np.full((window, w), NO_LOC, np.int32)
            old[:len(ids)] = wl[ids]
            wl2 = wl.copy()
            wl2[ids] = new[:len(ids)]
            index, dirty = backend.update(
                index, jnp.asarray(wl2), jnp.asarray(txn_ids),
                jnp.asarray(old), jnp.asarray(new))
            fresh = backend.build(jnp.asarray(wl2))
            for f in type(fresh)._fields:
                if f == "version":
                    continue
                np.testing.assert_array_equal(
                    np.asarray(getattr(index, f)),
                    np.asarray(getattr(fresh, f)),
                    err_msg=f"{backend.name}: field {f}")
            # resolutions agree too (update-index vs fresh-build-index)
            est = jnp.zeros((n,), jnp.bool_)
            inc = jnp.asarray(rng.integers(0, 5, n), jnp.int32)
            locs = jnp.asarray(rng.integers(-1, n_locs, 64), jnp.int32)
            readers = jnp.asarray(rng.integers(0, n + 1, 64), jnp.int32)
            wl2j = jnp.asarray(wl2)
            r_upd = jax.vmap(backend.make_resolver(index, wl2j, est, inc))(
                locs, readers)
            r_new = jax.vmap(backend.make_resolver(fresh, wl2j, est, inc))(
                locs, readers)
            for f, a, b in zip(r_upd._fields, r_upd, r_new):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=f"{backend.name}: {f}")
            # version bookkeeping: +1 exactly on dirty regions
            versions += np.asarray(dirty)
            np.testing.assert_array_equal(np.asarray(index.version), versions,
                                          err_msg=backend.name)
            wl = wl2


def test_clean_regions_are_byte_carries():
    """A wave touching one shard must not change any clean shard's segment
    bytes (segments may shift with the CSR offsets, contents may not)."""
    n, w = 16, 2
    backend = mv.ShardedBackend.from_universe(n, 64, 8)   # shard_size 8
    rng = np.random.default_rng(1)
    wl = rng.integers(0, 64, (n, w)).astype(np.int32)
    index = backend.build(jnp.asarray(wl))
    # txn 3 rewrites entirely inside shard 0 (locs < 8)
    txn_ids = np.full((4,), n, np.int32)
    txn_ids[0] = 3
    old = np.full((4, w), NO_LOC, np.int32)
    old[0] = wl[3]
    new = np.full((4, w), NO_LOC, np.int32)
    new[0] = [1, 5]
    wl2 = wl.copy()
    wl2[3] = new[0]
    index2, dirty = backend.update(index, jnp.asarray(wl2),
                                   jnp.asarray(txn_ids), jnp.asarray(old),
                                   jnp.asarray(new))
    dirty = np.asarray(dirty)
    expected_dirty = np.zeros(8, bool)
    expected_dirty[0] = True                      # new locs 1, 5
    for loc in wl[3]:
        expected_dirty[loc // 8] = True           # old entries dropped
    np.testing.assert_array_equal(dirty, expected_dirty)
    s1, s2 = np.asarray(index.starts), np.asarray(index2.starts)
    for s in np.nonzero(~dirty)[0]:
        assert s2[s + 1] - s2[s] == s1[s + 1] - s1[s], s
        for f in ("keys", "packed"):
            a = np.asarray(getattr(index, f))[s1[s]:s1[s + 1]]
            b = np.asarray(getattr(index2, f))[s2[s]:s2[s + 1]]
            np.testing.assert_array_equal(a, b, err_msg=f"shard {s} {f}")
    np.testing.assert_array_equal(np.asarray(index2.version),
                                  dirty.astype(np.int32))


def _contended_spec(contention):
    if contention == "high":
        return W.MixedSpec(
            p2p=W.P2PSpec(n_accounts=8), indirect=W.IndirectSpec(n_slots=8),
            admission=W.AdmissionSpec(n_tenants=2, n_groups=4,
                                      total_pages=10**6,
                                      quota_per_tenant=10**6))
    return W.MixedSpec(
        p2p=W.P2PSpec(n_accounts=400), indirect=W.IndirectSpec(n_slots=200),
        admission=W.AdmissionSpec(n_tenants=16, n_groups=64,
                                  total_pages=10**6, quota_per_tenant=10**5))


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**16),
       contention=st.sampled_from(["high", "low"]),
       backend=st.sampled_from(["sorted", "sharded"]))
def test_engine_incremental_equals_rebuild(seed, contention, backend):
    """incremental+skip ≡ rebuild+full-validation: snapshots, frontier, stats."""
    n = 32
    vm, params, storage, cfg = W.make_mixed_block(
        _contended_spec(contention), n, seed=seed, window=8)
    n_shards = 4 if backend == "sharded" else 0
    expected = run_sequential(vm, params, storage, n)
    stats = {}
    for variant in (
            dict(mv_update="rebuild", dirty_validation=False),
            dict(mv_update="incremental", dirty_validation=False),
            dict(mv_update="incremental", dirty_validation=True),
            # tiny cap: exercises the full-pass cond fallback every wave
            dict(mv_update="incremental", dirty_validation=True,
                 dirty_validation_cap=2)):
        c = dataclasses.replace(cfg, backend=backend, n_shards=n_shards,
                                **variant)
        res = run_block(vm, params, storage, c)
        assert bool(res.committed), variant
        np.testing.assert_array_equal(np.asarray(res.snapshot), expected,
                                      err_msg=str(variant))
        stats[tuple(sorted(variant.items()))] = (
            int(res.waves), int(res.execs), int(res.dep_aborts),
            int(res.val_aborts), int(res.wrote_new))
    assert len(set(stats.values())) == 1, stats


def test_engine_config_validates_new_knobs():
    with pytest.raises(ValueError, match="mv_update"):
        _cfg(8, 64, mv_update="lazy")
    with pytest.raises(ValueError, match="resolver_impl"):
        _cfg(8, 64, resolver_impl="cuda")
    with pytest.raises(ValueError, match="sharded"):
        _cfg(8, 64, resolver_impl="pallas")          # needs backend='sharded'
    c = _cfg(8, 64, backend="sharded", resolver_impl="pallas")
    assert c.dirty_cap() == 8                        # min(n_txns, ...)
    assert _cfg(100, 64, dirty_validation_cap=17).dirty_cap() == 17


# ---------------------------------------------------------------------------
# Region-resolve kernel: parity + engine selectability
# ---------------------------------------------------------------------------

def test_region_resolve_parity_on_shard_grid():
    """Kernel (interpret) vs segment_searchsorted on real built indexes."""
    from repro.kernels.mv_region_resolve import ops as rr_ops
    rng = np.random.default_rng(0)
    n, w = 32, 3
    for n_locs, n_shards in ((64, 4), (1000, 16), (50, 1)):
        backend = mv.ShardedBackend.from_universe(n, n_locs, n_shards)
        wl = np.where(rng.random((n, w)) < 0.7,
                      rng.integers(0, n_locs, (n, w)), NO_LOC).astype(np.int32)
        index = backend.build(jnp.asarray(wl))
        locs = rng.integers(0, n_locs, 257).astype(np.int32)
        readers = rng.integers(0, n + 1, 257).astype(np.int32)
        shard = np.clip(locs // backend.shard_size, 0, backend.n_shards - 1)
        q = (locs - shard * backend.shard_size) * (n + 1) + readers
        starts = np.asarray(index.starts)
        lo = jnp.asarray(starts[shard])
        hi = jnp.asarray(starts[shard + 1])
        want = rr_ops.region_searchsorted(index.keys, lo, hi,
                                          jnp.asarray(q), impl="xla")
        got = rr_ops.region_searchsorted(index.keys, lo, hi,
                                         jnp.asarray(q), impl="pallas",
                                         interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"L{n_locs}/s{n_shards}")


@pytest.mark.parametrize("block_q", [128, 512])
def test_region_resolve_kernel_block_sweep(block_q):
    from repro.kernels.mv_region_resolve import kernel as K, ref as R
    rng = np.random.default_rng(2)
    keys = np.sort(rng.integers(0, 10_000, 900)).astype(np.int32)
    edges = np.sort(rng.integers(0, 900, 2 * 1000)).reshape(2, -1)
    lo, hi = np.minimum(*edges).astype(np.int32), np.maximum(*edges).astype(np.int32)
    qs = rng.integers(-10, 10_010, 1000).astype(np.int32)
    got = K.segment_searchsorted_pallas(jnp.asarray(keys), jnp.asarray(lo),
                                        jnp.asarray(hi), jnp.asarray(qs),
                                        block_q=block_q, interpret=True)
    want = R.segment_searchsorted_ref(jnp.asarray(keys), jnp.asarray(lo),
                                      jnp.asarray(hi), jnp.asarray(qs))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_resolver_selectable_without_recompiles():
    """EngineConfig.resolver_impl='pallas': one jitted executor serves every
    contract mix (impl selection is config-static, not data-dependent), and
    commits the sequential snapshot."""
    n_txns, n_locs = 16, 2_000
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(ratios=(1, 1, 1)), n_txns, seed=0, n_locs=n_locs,
        window=4, backend="sharded", n_shards=4, resolver_impl="pallas")
    run = make_executor(vm, cfg)
    for i, ratios in enumerate([(1, 1, 1), (1, 1, 8)]):
        _, params, storage, _ = W.make_mixed_block(
            W.MixedSpec(ratios=ratios), n_txns, seed=20 + i, n_locs=n_locs,
            window=4, backend="sharded", n_shards=4, resolver_impl="pallas")
        res = run(params, storage)
        assert bool(res.committed)
        expected = run_sequential(vm, params, storage, n_txns)
        np.testing.assert_array_equal(np.asarray(res.snapshot), expected)
    assert run._cache_size() == 1, run._cache_size()

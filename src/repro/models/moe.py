"""Mixture-of-Experts layer: top-k routing, grouped capacity dispatch, EP.

TPU/SPMD layout (MaxText-style "dropping" implementation):
* tokens are reshaped to (G groups, group_size); capacity per expert is
  C = ceil(group_size * top_k * capacity_factor / E) within each group, so the
  dispatch/combine tensors are (G, gs, E, C) — total elements
  tokens * gs * top_k * cf, independent of E, tunable via group size.
* experts weights (E, D, F) are sharded E -> model (expert parallelism);
  dispatch groups G -> batch axes.  The combine einsum contracts the expert
  axis, producing one model-axis all-reduce per MoE layer — the EP collective.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import random

from repro.configs.base import ArchConfig
from repro.distributed.meshctx import BATCH, MODEL, constrain

F32 = jnp.float32


def capacity(cfg: ArchConfig) -> int:
    gs = cfg.moe_group_size
    c = int(gs * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, 1)


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = random.split(key, 4)
    gated = cfg.mlp_type in ("swiglu", "geglu")
    p = {
        "router": random.normal(ks[0], (d, e), F32) * d ** -0.5,
        "w_in": random.normal(ks[1], (e, d, f), dtype) * d ** -0.5,
        "w_out": random.normal(ks[2], (e, f, d), dtype) * f ** -0.5,
    }
    if gated:
        p["w_gate"] = random.normal(ks[3], (e, d, f), dtype) * d ** -0.5
    return p


def spec_moe(cfg: ArchConfig, fsdp: Optional[str]) -> dict:
    gated = cfg.mlp_type in ("swiglu", "geglu")
    p = {
        "router": (None, None),
        "w_in": (MODEL, fsdp, None),
        "w_out": (MODEL, None, fsdp),
    }
    if gated:
        p["w_gate"] = (MODEL, fsdp, None)
    return p


def moe(p, x: jax.Array, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss). Dropping tokens beyond capacity."""
    b, s, d = x.shape
    e, k, c = cfg.n_experts, cfg.top_k, capacity(cfg)
    gs = min(cfg.moe_group_size, b * s)
    tokens = x.reshape(-1, d)
    n = tokens.shape[0]
    pad = (-n) % gs
    tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    g = tokens.shape[0] // gs
    xt = tokens.reshape(g, gs, d)
    xt = constrain(xt, BATCH, None, None)

    logits = jnp.einsum("gsd,de->gse", xt.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (g, gs, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((e,), F32).at[gate_idx.reshape(-1)].add(
        jnp.ones_like(gate_idx.reshape(-1), F32)) / (g * gs * k)
    aux = e * jnp.sum(me * ce)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=F32)           # (g, gs, k, e)
    flatoh = onehot.reshape(g, gs * k, e)
    pos = jnp.cumsum(flatoh, axis=1) * flatoh - 1.0           # (g, gs*k, e)
    pos = pos.reshape(g, gs, k, e)
    in_cap = (pos >= 0) & (pos < c)
    pos_cap = jnp.clip(pos, 0, c - 1)
    # dispatch (g, gs, e, c) and combine (weighted) tensors
    cap_oh = jax.nn.one_hot(pos_cap, c, dtype=F32) * in_cap[..., None]
    disp = jnp.einsum("gske,gskec->gsec", onehot, cap_oh)
    comb = jnp.einsum("gske,gskec,gsk->gsec", onehot, cap_oh, gate_vals)
    disp = constrain(disp, BATCH, None, MODEL, None)
    comb = constrain(comb, BATCH, None, MODEL, None)

    xin = jnp.einsum("gsec,gsd->gecd", disp, xt.astype(F32))  # (g, e, c, d)
    xin = constrain(xin.astype(x.dtype), BATCH, MODEL, None, None)
    h = jnp.einsum("gecd,edf->gecf", xin, p["w_in"],
                   preferred_element_type=F32)
    if cfg.mlp_type in ("swiglu", "geglu"):
        gate = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"],
                          preferred_element_type=F32)
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else \
            (lambda t: jax.nn.gelu(t, approximate=True))
        h = act(gate) * h
    elif cfg.mlp_type == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h, approximate=True)
    out_e = jnp.einsum("gecf,efd->gecd", h.astype(x.dtype), p["w_out"],
                       preferred_element_type=F32)            # (g, e, c, d)
    out = jnp.einsum("gsec,gecd->gsd", comb, out_e)           # AR over model
    out = constrain(out.astype(x.dtype), BATCH, None, None)
    out = out.reshape(-1, d)[:n].reshape(b, s, d)
    return out, aux

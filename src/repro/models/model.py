"""Unified model API over all architecture families.

The runtime (train/serve/dryrun) only talks to this module:

    init_params(rng, cfg)              -> params pytree
    param_specs(cfg)                   -> PartitionSpec pytree (logical axes)
    train_logits(params, batch, cfg)   -> (logits, aux_loss)
    loss_fn(params, batch, cfg)        -> scalar loss
    init_cache / cache_specs           -> decode-state pytree
    decode_step(params, cache, tokens) -> (logits, new_cache)
    batch_struct(cfg, shape)           -> ShapeDtypeStruct batch (dry-run)
    batch_specs(cfg)                   -> PartitionSpec pytree for the batch
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.meshctx import BATCH
from repro.models import encdec, transformer

F32 = jnp.float32


def is_encdec(cfg: ArchConfig) -> bool:
    return cfg.encoder_layers > 0


def init_params(rng, cfg: ArchConfig, dtype=jnp.bfloat16):
    if is_encdec(cfg):
        return encdec.init_params(rng, cfg, dtype)
    return transformer.init_params(rng, cfg, dtype)


def param_specs(cfg: ArchConfig):
    if is_encdec(cfg):
        return encdec.param_specs(cfg)
    return transformer.param_specs(cfg)


# ---------------------------------------------------------------------------
# training forward + loss
# ---------------------------------------------------------------------------

def train_logits(params, batch: dict, cfg: ArchConfig, *, impl: str = "xla"):
    if is_encdec(cfg):
        return encdec.forward_train(params, batch["frames"], batch["tokens"],
                                    cfg, impl=impl)
    if cfg.frontend != "none":
        return transformer.logits_from_embeds(params, batch["embeds"], cfg,
                                              impl=impl)
    return transformer.logits_from_tokens(params, batch["tokens"], cfg,
                                          impl=impl)


def train_hidden(params, batch: dict, cfg: ArchConfig, *, impl: str = "xla"):
    """Final hidden states (B, S, D) — unembedding is done chunk-wise in the
    loss so the (B, S, V) f32 logits never materialize in full."""
    if is_encdec(cfg):
        return encdec.forward_train(params, batch["frames"], batch["tokens"],
                                    cfg, impl=impl, return_hidden=True)
    if cfg.frontend != "none":
        x = batch["embeds"]
    else:
        from repro.models import layers as L
        x = L.embed(params["embed"], batch["tokens"], cfg)
    return transformer.forward(params, x, cfg, impl=impl)


def _ce_chunk(embed_params, h_c, l_c, cfg: ArchConfig):
    """Cross-entropy on one sequence chunk (checkpointed)."""
    from repro.models import layers as L
    logits = L.unembed(embed_params, h_c, cfg).astype(F32)
    v = cfg.padded_vocab
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(l_c, 0, v - 1)[..., None], axis=-1)[..., 0]
    mask = (l_c >= 0).astype(F32)
    return ((logz - gold) * mask).sum(), mask.sum()


def loss_fn(params, batch: dict, cfg: ArchConfig, *, impl: str = "xla",
            ce_chunk: int = 512):
    hidden, aux = train_hidden(params, batch, cfg, impl=impl)
    labels = batch["labels"]
    b, s, d = hidden.shape
    chunk = min(ce_chunk, s)
    if s % chunk:
        chunk = s
    nc = s // chunk
    h_c = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    l_c = labels.reshape(b, nc, chunk).swapaxes(0, 1)
    ce = jax.checkpoint(
        lambda hc, lc: _ce_chunk(params["embed"], hc, lc, cfg))

    def body(carry, xs):
        nll, cnt = carry
        hc, lc = xs
        n, c = ce(hc, lc)
        return (nll + n, cnt + c), None

    (nll, cnt), _ = jax.lax.scan(body, (jnp.zeros((), F32),
                                        jnp.zeros((), F32)), (h_c, l_c))
    loss = nll / jnp.maximum(cnt, 1.0)
    if cfg.n_experts:
        loss = loss + 0.01 * aux
    return loss


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    if is_encdec(cfg):
        return encdec.init_cache(cfg, batch, max_seq, dtype)
    return transformer.init_cache(cfg, batch, max_seq, dtype)


def cache_specs(cfg: ArchConfig):
    if is_encdec(cfg):
        return encdec.cache_specs(cfg)
    return transformer.cache_specs(cfg)


def decode_step(params, cache, tokens: jax.Array, cfg: ArchConfig):
    if is_encdec(cfg):
        return encdec.decode_step(params, cache, tokens, cfg)
    return transformer.decode_step(params, cache, tokens, cfg)


# ---------------------------------------------------------------------------
# dry-run input structures (ShapeDtypeStruct — never allocated)
# ---------------------------------------------------------------------------

def batch_struct(cfg: ArchConfig, shape: ShapeConfig,
                 dtype=jnp.bfloat16) -> dict:
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"tokens": sds((b,), jnp.int32)}
    if is_encdec(cfg):
        return {"frames": sds((b, encdec.ENC_FRAMES, cfg.d_model), dtype),
                "tokens": sds((b, s), jnp.int32),
                "labels": sds((b, s), jnp.int32)}
    if cfg.frontend != "none":
        return {"embeds": sds((b, s, cfg.d_model), dtype),
                "labels": sds((b, s), jnp.int32)}
    return {"tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32)}


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    if shape.kind == "decode":
        return {"tokens": (BATCH,)}
    if is_encdec(cfg):
        return {"frames": (BATCH, None, None), "tokens": (BATCH, None),
                "labels": (BATCH, None)}
    if cfg.frontend != "none":
        return {"embeds": (BATCH, None, None), "labels": (BATCH, None)}
    return {"tokens": (BATCH, None), "labels": (BATCH, None)}


def make_host_batch(cfg: ArchConfig, batch: int, seq: int, rng=None,
                    dtype=jnp.float32) -> dict:
    """Small concrete batch for CPU smoke tests."""
    import numpy as np
    r = np.random.default_rng(0 if rng is None else rng)
    if is_encdec(cfg):
        return {
            "frames": jnp.asarray(
                r.standard_normal((batch, 8, cfg.d_model)), dtype),
            "tokens": jnp.asarray(
                r.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
            "labels": jnp.asarray(
                r.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        }
    if cfg.frontend != "none":
        return {
            "embeds": jnp.asarray(
                r.standard_normal((batch, seq, cfg.d_model)), dtype),
            "labels": jnp.asarray(
                r.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(
            r.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(
            r.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
    }

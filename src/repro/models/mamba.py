"""Mamba-1 (selective scan) and Mamba-2 (SSD, chunked) blocks.

Sharding: the expanded channel axis ``d_inner`` (and Mamba-2 heads) shards
over `model`; sequence stays unsharded (the scan is sequential in time).
Train/prefill use the log-depth associative scan (XLA) or the Pallas
``selective_scan`` kernel; decode is the O(1)-per-token recurrence on a
carried state — this is what makes the ``long_500k`` shape tractable.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import random

from repro.configs.base import ArchConfig
from repro.distributed.meshctx import BATCH, MODEL, constrain
from repro.kernels.selective_scan import ops as scan_ops

F32 = jnp.float32


def dt_rank(cfg: ArchConfig) -> int:
    return -(-cfg.d_model // 16)


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, T, C); w: (C, K); causal depthwise conv."""
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(F32), w.T[:, None, :].astype(F32),   # (K, 1, C) OIW? see dn
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[0])
    return (out + b.astype(F32)).astype(x.dtype)


def _conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
               b: jax.Array):
    """x_t: (B, C); conv_state: (B, K-1, C) -> (y_t, new_state)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", window.astype(F32), w.astype(F32)) \
        + b.astype(F32)
    return y.astype(x_t.dtype), window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def init_mamba1(key, cfg: ArchConfig, dtype) -> dict:
    d, di, n, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    r = dt_rank(cfg)
    ks = random.split(key, 6)
    return {
        "in_proj": random.normal(ks[0], (d, 2 * di), dtype) * d ** -0.5,
        "conv_w": random.normal(ks[1], (di, k), dtype) * k ** -0.5,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": random.normal(ks[2], (di, r + 2 * n), dtype) * di ** -0.5,
        "dt_proj": random.normal(ks[3], (r, di), dtype) * r ** -0.5,
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(random.uniform(ks[4], (di,), F32) *
                    (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)))
        ).astype(dtype),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=F32), (di, 1))
                         ).astype(F32),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": random.normal(ks[5], (di, d), dtype) * di ** -0.5,
    }


def spec_mamba1(cfg: ArchConfig, fsdp: Optional[str]) -> dict:
    return {
        "in_proj": (fsdp, MODEL),
        "conv_w": (MODEL, None), "conv_b": (MODEL,),
        "x_proj": (MODEL, None),
        "dt_proj": (None, MODEL), "dt_bias": (MODEL,),
        "a_log": (MODEL, None), "d_skip": (MODEL,),
        "out_proj": (MODEL, fsdp),
    }


def _mamba1_core(p, xz, cfg: ArchConfig, impl: str):
    """xz: (B, T, 2*di) post in_proj -> y (B, T, di) pre out_proj."""
    di, n = cfg.d_inner, cfg.ssm_state
    r = dt_rank(cfg)
    x, z = jnp.split(xz, 2, axis=-1)
    x = _causal_conv(x, p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x.astype(F32)).astype(x.dtype)
    proj = jnp.einsum("btc,cr->btr", x, p["x_proj"],
                      preferred_element_type=F32)
    dt, b, c = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rc->btc", dt, p["dt_proj"].astype(F32))
        + p["dt_bias"].astype(F32))
    a = -jnp.exp(p["a_log"])                                   # (di, n)
    y = scan_ops.selective_scan(x, dt.astype(x.dtype), a,
                                b.astype(x.dtype), c.astype(x.dtype),
                                impl=impl)
    y = y + x * p["d_skip"].astype(x.dtype)
    return y * jax.nn.silu(z.astype(F32)).astype(x.dtype)


def mamba1(p, x: jax.Array, cfg: ArchConfig, *, impl: str = "xla") -> jax.Array:
    """x: (B, T, D) -> (B, T, D)."""
    xz = jnp.einsum("btd,dc->btc", x, p["in_proj"],
                    preferred_element_type=F32).astype(x.dtype)
    xz = constrain(xz, BATCH, None, MODEL)
    y = _mamba1_core(p, xz, cfg, impl)
    out = jnp.einsum("btc,cd->btd", y, p["out_proj"],
                     preferred_element_type=F32).astype(x.dtype)
    return constrain(out, BATCH, None, None)


def mamba1_init_state(cfg: ArchConfig, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), F32),
    }


def mamba1_state_spec(cfg: ArchConfig):
    return {"conv": (BATCH, None, MODEL), "ssm": (BATCH, MODEL, None)}


def mamba1_decode(p, state: dict, x_t: jax.Array, cfg: ArchConfig):
    """x_t: (B, D) one token -> (y_t, new_state)."""
    di, n = cfg.d_inner, cfg.ssm_state
    r = dt_rank(cfg)
    xz = jnp.einsum("bd,dc->bc", x_t, p["in_proj"],
                    preferred_element_type=F32).astype(x_t.dtype)
    x, z = jnp.split(xz, 2, axis=-1)
    x, conv_state = _conv_step(x, state["conv"], p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x.astype(F32)).astype(x_t.dtype)
    proj = jnp.einsum("bc,cr->br", x, p["x_proj"], preferred_element_type=F32)
    dt, b, c = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("br,rc->bc", dt.astype(x_t.dtype), p["dt_proj"],
                   preferred_element_type=F32)
        + p["dt_bias"].astype(F32))                           # (B, di)
    a = -jnp.exp(p["a_log"])                                   # (di, n)
    decay = jnp.exp(dt[..., None] * a[None])                   # (B, di, n)
    h = decay * state["ssm"] + (dt * x.astype(F32))[..., None] * b[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h, c)
    y = y + x.astype(F32) * p["d_skip"].astype(F32)
    y = (y * jax.nn.silu(z.astype(F32))).astype(x_t.dtype)
    out = jnp.einsum("bc,cd->bd", y, p["out_proj"],
                     preferred_element_type=F32).astype(x_t.dtype)
    return out, {"conv": conv_state, "ssm": h}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) — chunked scan, one scalar A per head.
# ---------------------------------------------------------------------------

def m2_heads(cfg: ArchConfig) -> int:
    if cfg.ssm_heads:
        return cfg.ssm_heads
    return cfg.d_inner // cfg.ssm_state      # head_dim == ssm_state default


def init_mamba2(key, cfg: ArchConfig, dtype) -> dict:
    d, di, n, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    h = m2_heads(cfg)
    ks = random.split(key, 4)
    conv_dim = di + 2 * n                       # conv over (x, B, C)
    return {
        "in_proj": random.normal(
            ks[0], (d, 2 * di + 2 * n + h), dtype) * d ** -0.5,
        "conv_w": random.normal(ks[1], (conv_dim, k), dtype) * k ** -0.5,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(random.uniform(ks[2], (h,), F32) * 15 + 1),
        "dt_bias": jnp.zeros((h,), F32),
        "d_skip": jnp.ones((h,), dtype),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": random.normal(ks[3], (di, d), dtype) * di ** -0.5,
    }


def spec_mamba2(cfg: ArchConfig, fsdp: Optional[str]) -> dict:
    return {
        "in_proj": (fsdp, MODEL),
        "conv_w": (MODEL, None), "conv_b": (MODEL,),
        "a_log": (MODEL,), "dt_bias": (MODEL,), "d_skip": (MODEL,),
        "norm_scale": (MODEL,),
        "out_proj": (MODEL, fsdp),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., q) -> (..., q, q) lower-tri pairwise sums s[i,j]=sum(j<k<=i)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int = 128):
    """SSD forward.

    x: (B, L, H, P); dt: (B, L, H); a: (H,) (negative);
    b, c: (B, L, N) (single group, broadcast across heads).
    Returns y: (B, L, H, P).

    Every (…, H, …) intermediate carries an explicit head->model sharding
    constraint: GSPMD drops the head sharding through the chunking reshapes
    otherwise, replicating multi-GiB decay masks on every chip.
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q
    xc = x.reshape(bsz, nc, q, h, p).astype(F32)
    xc = constrain(xc, BATCH, None, None, MODEL, None)
    dtc = dt.reshape(bsz, nc, q, h).astype(F32)
    dtc = constrain(dtc, BATCH, None, None, MODEL)
    bc = b.reshape(bsz, nc, q, n).astype(F32)
    cc = c.reshape(bsz, nc, q, n).astype(F32)
    abar = dtc * a[None, None, None, :]                     # (B,nc,q,H)

    # checkpointed intra-chunk work: the (B,nc,H,q,q) decay mask and the
    # score block are recomputed in backward rather than saved.
    @jax.checkpoint
    def intra_chunk(abar, cc, bc, dtc, xc):
        lmask = jnp.exp(_segsum(abar.transpose(0, 1, 3, 2)))  # (B,nc,H,q,q)
        lmask = constrain(lmask, BATCH, None, MODEL, None, None)
        scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)        # (B,nc,q,q)
        masked = jnp.einsum("bcqk,bchqk->bchqk", scores, lmask)
        masked = constrain(masked, BATCH, None, MODEL, None, None)
        return jnp.einsum("bchqk,bckh,bckhp->bcqhp", masked, dtc, xc)

    y_diag = intra_chunk(abar, cc, bc, dtc, xc)
    y_diag = constrain(y_diag, BATCH, None, None, MODEL, None)

    # 2. chunk states: S_c = sum_k decay_out[k] * dt_k * B_k ⊗ x_k
    a_cum = jnp.cumsum(abar, axis=2)                        # (B,nc,q,H)
    a_tot = a_cum[:, :, -1:, :]                             # (B,nc,1,H)
    decay_out = jnp.exp(a_tot - a_cum)                      # (B,nc,q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchnp",
                        bc, decay_out * dtc, xc)            # (B,nc,H,N,P)
    states = constrain(states, BATCH, None, MODEL, None, None)

    # 3. inter-chunk recurrence over nc: S'_{c} = G_c S'_{c-1} + S_c
    gdec = jnp.exp(a_tot[:, :, 0, :])                       # (B,nc,H)

    def combine(p1, p2):
        (g1, s1), (g2, s2) = p1, p2
        return g1 * g2, g2[..., None, None] * s1 + s2

    _, s_run = jax.lax.associative_scan(combine, (gdec, states), axis=1)
    s_prev = jnp.concatenate(
        [jnp.zeros_like(s_run[:, :1]), s_run[:, :-1]], axis=1)
    s_prev = constrain(s_prev, BATCH, None, MODEL, None, None)

    # 4. inter-chunk contribution
    decay_in = jnp.exp(a_cum)                               # (B,nc,q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", cc, decay_in, s_prev)

    y = (y_diag + y_off).reshape(bsz, nc * q, h, p)[:, :l]
    return y.astype(x.dtype), s_run[:, -1]                  # final state


def mamba2(p, x: jax.Array, cfg: ArchConfig, *, chunk: int = 128) -> jax.Array:
    """x: (B, T, D) -> (B, T, D)."""
    di, n = cfg.d_inner, cfg.ssm_state
    h = m2_heads(cfg)
    hp = di // h
    proj = jnp.einsum("btd,dc->btc", x, p["in_proj"],
                      preferred_element_type=F32).astype(x.dtype)
    proj = constrain(proj, BATCH, None, MODEL)
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc.astype(F32)).astype(x.dtype)
    xs, b, c = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))
    a = -jnp.exp(p["a_log"])
    # heads shard over model; re-assert after the channel->(H,P) reshape
    # (sharding can be dropped through reshapes, exploding SSD intermediates)
    xh = constrain(xs.reshape(*xs.shape[:2], h, hp), BATCH, None, MODEL, None)
    dt = constrain(dt, BATCH, None, MODEL)
    y, _ = ssd_chunked(xh, dt, a, b, c, chunk=chunk)
    y = constrain(y, BATCH, None, MODEL, None)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(*xs.shape[:2], di)
    # gated RMSNorm (mamba2)
    yf = y.astype(F32) * jax.nn.silu(z.astype(F32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"].astype(F32)
    out = jnp.einsum("btc,cd->btd", yf.astype(x.dtype), p["out_proj"],
                     preferred_element_type=F32).astype(x.dtype)
    return constrain(out, BATCH, None, None)


def mamba2_init_state(cfg: ArchConfig, batch: int, dtype):
    h = m2_heads(cfg)
    hp = cfg.d_inner // h
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, cfg.ssm_state, hp), F32),
    }


def mamba2_state_spec(cfg: ArchConfig):
    return {"conv": (BATCH, None, MODEL), "ssm": (BATCH, MODEL, None, None)}


def mamba2_decode(p, state: dict, x_t: jax.Array, cfg: ArchConfig):
    """x_t: (B, D) -> (y_t, new_state)."""
    di, n = cfg.d_inner, cfg.ssm_state
    h = m2_heads(cfg)
    hp = di // h
    proj = jnp.einsum("bd,dc->bc", x_t, p["in_proj"],
                      preferred_element_type=F32).astype(x_t.dtype)
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc, conv_state = _conv_step(xbc, state["conv"], p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc.astype(F32)).astype(x_t.dtype)
    xs, b, c = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None])                          # (B,H)
    xh = xs.reshape(-1, h, hp).astype(F32)
    ssm = decay[..., None, None] * state["ssm"] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, b.astype(F32), xh)
    y = jnp.einsum("bn,bhnp->bhp", c.astype(F32), ssm)
    y = y + xh * p["d_skip"].astype(F32)[None, :, None]
    y = y.reshape(-1, di)
    yf = y * jax.nn.silu(z.astype(F32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"].astype(F32)
    out = jnp.einsum("bc,cd->bd", yf.astype(x_t.dtype), p["out_proj"],
                     preferred_element_type=F32).astype(x_t.dtype)
    return out, {"conv": conv_state, "ssm": ssm}

"""Decoder-only model assembly: dense, MoE, SSM and hybrid stacks.

* Layers are stacked (leading layer axis) and iterated with ``lax.scan`` so
  the HLO contains one layer body regardless of depth — essential for
  compiling 96-layer × 18k-width configs in the dry-run.
* ``remat='block'`` wraps the scanned body in ``jax.checkpoint`` (full-block
  policy) for activation-memory control at train shapes.
* Hybrid (zamba2): a single *shared* attention+MLP block (one set of weights)
  is applied every ``attn_every`` Mamba-2 layers, each application site with
  its own KV cache at decode time.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import random

from repro.configs.base import ArchConfig
from repro.distributed.meshctx import BATCH, MODEL, constrain
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE

F32 = jnp.float32


# ---------------------------------------------------------------------------
# per-layer init/spec
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, dtype) -> dict:
    """One decoder block (attention | mamba | + mlp/moe per family)."""
    ks = random.split(key, 4)
    if cfg.family == "ssm":
        return {"ln1": L.init_rmsnorm(cfg.d_model, dtype),
                "mixer": M.init_mamba1(ks[0], cfg, dtype)}
    if cfg.family == "hybrid":
        return {"ln1": L.init_rmsnorm(cfg.d_model, dtype),
                "mixer": M.init_mamba2(ks[0], cfg, dtype)}
    p = {"ln1": L.init_rmsnorm(cfg.d_model, dtype),
         "attn": L.init_attention(ks[0], cfg, dtype),
         "ln2": L.init_rmsnorm(cfg.d_model, dtype)}
    if cfg.n_experts:
        p["moe"] = MOE.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg, dtype)
    return p


def _spec_block(cfg: ArchConfig, fsdp: Optional[str]) -> dict:
    if cfg.family == "ssm":
        return {"ln1": L.spec_rmsnorm(), "mixer": M.spec_mamba1(cfg, fsdp)}
    if cfg.family == "hybrid":
        return {"ln1": L.spec_rmsnorm(), "mixer": M.spec_mamba2(cfg, fsdp)}
    p = {"ln1": L.spec_rmsnorm(), "attn": L.spec_attention(cfg, fsdp),
         "ln2": L.spec_rmsnorm()}
    if cfg.n_experts:
        p["moe"] = MOE.spec_moe(cfg, fsdp)
    else:
        p["mlp"] = L.spec_mlp(cfg, fsdp)
    return p


def _init_shared_attn(key, cfg: ArchConfig, dtype) -> dict:
    ks = random.split(key, 2)
    return {"ln1": L.init_rmsnorm(cfg.d_model, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "ln2": L.init_rmsnorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(ks[1], cfg, dtype)}


def _spec_shared_attn(cfg: ArchConfig, fsdp: Optional[str]) -> dict:
    return {"ln1": L.spec_rmsnorm(), "attn": L.spec_attention(cfg, fsdp),
            "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp(cfg, fsdp)}


# ---------------------------------------------------------------------------
# model init / specs
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    ke, kl, ks = random.split(key, 3)
    lkeys = random.split(kl, cfg.n_layers)
    p = {
        "embed": L.init_embed(ke, cfg, dtype),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg, dtype))(lkeys),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.family == "hybrid":
        p["shared"] = _init_shared_attn(ks, cfg, dtype)
    return p


def param_specs(cfg: ArchConfig) -> dict:
    fsdp = "data" if cfg.fsdp else None
    block = _spec_block(cfg, fsdp)
    stacked = jax.tree_util.tree_map(
        lambda s: (None,) + tuple(s), block,
        is_leaf=lambda s: isinstance(s, tuple))
    p = {
        "embed": L.spec_embed(cfg, fsdp),
        "blocks": stacked,
        "final_norm": L.spec_rmsnorm(),
    }
    if cfg.family == "hybrid":
        p["shared"] = _spec_shared_attn(cfg, fsdp)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_fwd(lp, x, cfg: ArchConfig, impl: str):
    # Megatron-style sequence parallelism on the residual stream: norms and
    # residual adds run seq-sharded over `model`; attention/MLP internals
    # re-shard as needed (all-gather / reduce-scatter inserted by SPMD).
    # SSM/hybrid mixers consume the full sequence (recurrent scan), so their
    # residual stays seq-replicated — seq-sharding would buy nothing and cost
    # an all-gather + reduce-scatter per layer.
    if x.shape[1] > 1 and cfg.family not in ("ssm", "hybrid"):
        x = constrain(x, BATCH, MODEL, None)
    if cfg.family in ("ssm", "hybrid"):
        mixer = M.mamba1 if cfg.family == "ssm" else M.mamba2
        return x + mixer(lp["mixer"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps),
                         cfg), jnp.zeros((), F32)
    h = x + L.attention(lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps),
                        cfg, impl=impl)
    z = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
    if cfg.n_experts:
        out, aux = MOE.moe(lp["moe"], z, cfg)
    else:
        out, aux = L.mlp(lp["mlp"], z, cfg), jnp.zeros((), F32)
    return h + out, aux


def _shared_fwd(sp, x, cfg: ArchConfig, impl: str):
    h = x + L.attention(sp["attn"], L.rmsnorm(sp["ln1"], x, cfg.norm_eps),
                        cfg, impl=impl)
    return h + L.mlp(sp["mlp"], L.rmsnorm(sp["ln2"], h, cfg.norm_eps), cfg)


def forward(params, x: jax.Array, cfg: ArchConfig, *,
            impl: str = "xla") -> tuple[jax.Array, jax.Array]:
    """Hidden-states forward. x: (B, S, D) -> (hidden (B,S,D), aux_loss)."""

    def body(carry, scanned):
        h, aux, i = carry
        lp = scanned
        if cfg.family == "hybrid":
            h = jax.lax.cond(
                i % cfg.attn_every == 0,
                lambda v: _shared_fwd(params["shared"], v, cfg, impl),
                lambda v: v, h)
        h, a = _block_fwd(lp, h, cfg, impl)
        return (h, aux + a, i + 1), None

    if cfg.remat == "block":
        body = jax.checkpoint(body)

    (x, aux, _), _ = jax.lax.scan(
        body, (x, jnp.zeros((), F32), jnp.asarray(0, jnp.int32)),
        params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def logits_from_tokens(params, tokens: jax.Array, cfg: ArchConfig, *,
                       impl: str = "xla"):
    x = L.embed(params["embed"], tokens, cfg)
    h, aux = forward(params, x, cfg, impl=impl)
    return L.unembed(params["embed"], h, cfg), aux


def logits_from_embeds(params, embeds: jax.Array, cfg: ArchConfig, *,
                       impl: str = "xla"):
    """Frontend-stub path ([vlm]/[audio]): precomputed patch/frame embeds."""
    h, aux = forward(params, embeds, cfg, impl=impl)
    return L.unembed(params["embed"], h, cfg), aux


# ---------------------------------------------------------------------------
# decode (one token against a cache)
# ---------------------------------------------------------------------------

def n_shared_sites(cfg: ArchConfig) -> int:
    if cfg.family != "hybrid":
        return 0
    return -(-cfg.n_layers // cfg.attn_every)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Stacked per-layer decode state."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.family == "ssm":
        state = jax.vmap(lambda _: M.mamba1_init_state(cfg, batch, dtype))(
            jnp.arange(cfg.n_layers))
        return {"ssm": state, "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        state = jax.vmap(lambda _: M.mamba2_init_state(cfg, batch, dtype))(
            jnp.arange(cfg.n_layers))
        sites = n_shared_sites(cfg)
        return {"ssm": state,
                "k": jnp.zeros((sites, batch, max_seq, kv, hd), dtype),
                "v": jnp.zeros((sites, batch, max_seq, kv, hd), dtype),
                "pos": jnp.zeros((), jnp.int32)}
    return {"k": jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype),
            "pos": jnp.zeros((), jnp.int32)}


def cache_specs(cfg: ArchConfig):
    kvspec = (None,) + L.cache_spec(cfg)
    if cfg.family == "ssm":
        st = jax.tree_util.tree_map(
            lambda s: (None,) + tuple(s), M.mamba1_state_spec(cfg),
            is_leaf=lambda s: isinstance(s, tuple))
        return {"ssm": st, "pos": ()}
    if cfg.family == "hybrid":
        st = jax.tree_util.tree_map(
            lambda s: (None,) + tuple(s), M.mamba2_state_spec(cfg),
            is_leaf=lambda s: isinstance(s, tuple))
        return {"ssm": st, "k": kvspec, "v": kvspec, "pos": ()}
    return {"k": kvspec, "v": kvspec, "pos": ()}


def decode_step(params, cache, tokens: jax.Array, cfg: ArchConfig):
    """tokens: (B,) -> (logits (B, V), new_cache)."""
    pos = cache["pos"]
    x = L.embed(params["embed"], tokens[:, None], cfg)     # (B, 1, D)

    if cfg.family == "ssm":
        def body(h, scanned):
            lp, st = scanned
            y, st2 = M.mamba1_decode(lp["mixer"],
                                     st, L.rmsnorm(lp["ln1"], h, cfg.norm_eps)[:, 0],
                                     cfg)
            return h + y[:, None, :], st2
        h, new_state = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        new_cache = {"ssm": new_state, "pos": pos + 1}
    elif cfg.family == "hybrid":
        def body(carry, scanned):
            h, k_all, v_all, i = carry
            lp, st = scanned

            def with_attn(h):
                site = i // cfg.attn_every
                ck = jax.lax.dynamic_index_in_dim(k_all, site, 0, False)
                cv = jax.lax.dynamic_index_in_dim(v_all, site, 0, False)
                y, ck, cv = L.attention_decode(
                    params["shared"]["attn"],
                    L.rmsnorm(params["shared"]["ln1"], h, cfg.norm_eps),
                    ck, cv, pos, cfg)
                h = h + y
                h = h + L.mlp(params["shared"]["mlp"],
                              L.rmsnorm(params["shared"]["ln2"], h,
                                        cfg.norm_eps), cfg)
                return (h,
                        jax.lax.dynamic_update_index_in_dim(k_all, ck, site, 0),
                        jax.lax.dynamic_update_index_in_dim(v_all, cv, site, 0))

            h, k_all, v_all = jax.lax.cond(
                i % cfg.attn_every == 0, with_attn,
                lambda h_: (h_, k_all, v_all), h)
            y, st2 = M.mamba2_decode(lp["mixer"],
                                     st,
                                     L.rmsnorm(lp["ln1"], h, cfg.norm_eps)[:, 0],
                                     cfg)
            return (h + y[:, None, :], k_all, v_all, i + 1), st2

        (h, k_all, v_all, _), new_state = jax.lax.scan(
            body, (x, cache["k"], cache["v"], jnp.asarray(0, jnp.int32)),
            (params["blocks"], cache["ssm"]))
        new_cache = {"ssm": new_state, "k": k_all, "v": v_all, "pos": pos + 1}
    else:
        def body(h, scanned):
            lp, ck, cv = scanned
            y, ck, cv = L.attention_decode(
                lp["attn"], L.rmsnorm(lp["ln1"], h, cfg.norm_eps), ck, cv,
                pos, cfg)
            h = h + y
            z = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
            if cfg.n_experts:
                out, _ = MOE.moe(lp["moe"], z, cfg)
            else:
                out = L.mlp(lp["mlp"], z, cfg)
            return h + out, (ck, cv)

        h, (k_new, v_new) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = L.unembed(params["embed"], h, cfg)[:, 0]
    return logits, new_cache

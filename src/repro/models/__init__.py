"""Model zoo: dense/GQA, MoE (EP), Mamba-1/2, hybrid, enc-dec, stubs."""

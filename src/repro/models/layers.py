"""Shared transformer layers: norms, RoPE, GQA attention, MLP variants.

Conventions
-----------
* Params are plain nested dicts of jnp arrays; every ``init_*`` has a sibling
  ``spec_*`` returning the identically-structured tree of PartitionSpec
  tuples (logical axes; see repro.distributed.meshctx).
* Attention supports two TP layouts, chosen per-arch by head divisibility:
    - 'heads'    : Q (and KV when divisible) heads sharded over `model`
    - 'sequence' : context parallelism — activations sharded over `model`
                   on the sequence axis; attention weights FSDP-only
* All matmuls accumulate in f32 (`preferred_element_type`), params stored in
  the config dtype (bf16 for the big dry-run configs, f32 for smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import random

from repro.configs.base import ArchConfig
from repro.distributed.meshctx import BATCH, MODEL, constrain
from repro.kernels.flash_attention import ops as fa_ops

F32 = jnp.float32


def attn_mode(cfg: ArchConfig, tp: int = 16) -> str:
    """'heads' TP when the query heads divide the model axis, else 'sequence'."""
    return "heads" if cfg.n_heads % tp == 0 else "sequence"


def kv_sharded(cfg: ArchConfig, tp: int = 16) -> bool:
    return cfg.n_kv_heads % tp == 0


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def spec_rmsnorm() -> dict:
    return {"scale": (None,)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(F32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def spec_layernorm() -> dict:
    return {"scale": (None,), "bias": (None,)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(F32) + p["bias"].astype(F32)).astype(x.dtype)


def norm(p: dict, x: jax.Array, cfg, eps: float | None = None) -> jax.Array:
    eps = cfg.norm_eps if eps is None else eps
    if cfg.norm_type == "layernorm":
        return layernorm(p, x, eps)
    return rmsnorm(p, x, eps)


def init_norm(cfg, dtype) -> dict:
    if cfg.norm_type == "layernorm":
        return init_layernorm(cfg.d_model, dtype)
    return init_rmsnorm(cfg.d_model, dtype)


def spec_norm(cfg) -> dict:
    if cfg.norm_type == "layernorm":
        return spec_layernorm()
    return spec_rmsnorm()


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Whisper-style absolute sinusoidal position encodings (S, D)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=F32) * (jnp.log(10000.0) / (half - 1)))
    angles = jnp.arange(seq, dtype=F32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with D even; positions: (..., S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    angles = positions.astype(F32)[..., None] * freqs      # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": random.normal(k1, (d, h, hd), dtype) * scale,
        "wk": random.normal(k2, (d, kv, hd), dtype) * scale,
        "wv": random.normal(k3, (d, kv, hd), dtype) * scale,
        "wo": random.normal(k4, (h, hd, d), dtype) * (h * hd) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def spec_attention(cfg: ArchConfig, fsdp: Optional[str]) -> dict:
    mode = attn_mode(cfg)
    head_ax = MODEL if mode == "heads" else None
    kv_ax = MODEL if (mode == "heads" and kv_sharded(cfg)) else None
    p = {
        "wq": (fsdp, head_ax, None),
        "wk": (fsdp, kv_ax, None),
        "wv": (fsdp, kv_ax, None),
        "wo": (head_ax, None, fsdp),
    }
    if cfg.qkv_bias:
        p["bq"] = (head_ax, None)
        p["bk"] = (kv_ax, None)
        p["bv"] = (kv_ax, None)
    return p


def _project_qkv(p, x, cfg: ArchConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=F32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"],
                   preferred_element_type=F32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"],
                   preferred_element_type=F32).astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention(p, x, cfg: ArchConfig, *, causal: bool = True,
              positions: Optional[jax.Array] = None,
              impl: str = "xla") -> jax.Array:
    """Full (training / prefill) self-attention. x: (B, S, D)."""
    b, s, _ = x.shape
    mode = attn_mode(cfg)
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    if mode == "sequence":
        x = constrain(x, BATCH, MODEL, None)
    q, k, v = _project_qkv(p, x, cfg, positions)
    if mode == "heads":
        q = constrain(q, BATCH, None, MODEL, None)
        kv_ax = MODEL if kv_sharded(cfg) else None
        k = constrain(k, BATCH, None, kv_ax, None)
        v = constrain(v, BATCH, None, kv_ax, None)
    else:
        q = constrain(q, BATCH, MODEL, None, None)
        # context parallelism: every shard sees full K/V (XLA all-gathers).
        k = constrain(k, BATCH, None, None, None)
        v = constrain(v, BATCH, None, None, None)
    out = fa_ops.attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                           v.swapaxes(1, 2), causal=causal,
                           scale=cfg.resolved_head_dim ** -0.5, impl=impl,
                           expand_kv=(mode == "heads"))
    out = out.swapaxes(1, 2)                              # (B, S, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                   preferred_element_type=F32).astype(x.dtype)
    if mode == "sequence":
        y = constrain(y, BATCH, MODEL, None)
    return y


def attention_decode(p, x, cache_k, cache_v, pos, cfg: ArchConfig):
    """One-token decode. x: (B, 1, D); cache_[kv]: (B, S_cache, KV, hd).

    Returns (y, new_cache_k, new_cache_v). The cache is sharded over kv-heads
    (when divisible) or over the sequence axis (partial-softmax reductions
    become tiny model-axis all-reduces under SPMD).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, pos, 0, 0))
    group = cfg.n_heads // cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    qh = q[:, 0].reshape(b, cfg.n_kv_heads, group, hd)
    # native-dtype operands + f32 accumulation: no f32 copy of the cache
    # (an .astype on the scanned cache gets hoisted by XLA into a full
    # f32 materialization of the stacked cache).
    s = jnp.einsum("bkgd,bskd->bkgs", qh, cache_k,
                   preferred_element_type=F32) * hd ** -0.5
    seq = jnp.arange(cache_k.shape[1])[None, None, None, :]
    s = jnp.where(seq <= pos, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w.astype(cache_v.dtype), cache_v,
                   preferred_element_type=F32)
    o = o.reshape(b, 1, cfg.n_heads, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                   preferred_element_type=F32).astype(x.dtype)
    return y, cache_k, cache_v


def cache_spec(cfg: ArchConfig):
    """PartitionSpec (logical) for a (B, S, KV, hd) cache tensor."""
    if kv_sharded(cfg):
        return (BATCH, None, MODEL, None)
    return (BATCH, MODEL, None, None)     # shard the sequence axis


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, dtype, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    gated = cfg.mlp_type in ("swiglu", "geglu")
    ks = random.split(key, 3)
    p = {
        "w_in": random.normal(ks[0], (d, f), dtype) * d ** -0.5,
        "w_out": random.normal(ks[1], (f, d), dtype) * f ** -0.5,
    }
    if gated:
        p["w_gate"] = random.normal(ks[2], (d, f), dtype) * d ** -0.5
    return p


def spec_mlp(cfg: ArchConfig, fsdp: Optional[str]) -> dict:
    gated = cfg.mlp_type in ("swiglu", "geglu")
    p = {"w_in": (fsdp, MODEL), "w_out": (MODEL, fsdp)}
    if gated:
        p["w_gate"] = (fsdp, MODEL)
    return p


def mlp(p, x, cfg: ArchConfig) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"],
                   preferred_element_type=F32)
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"],
                       preferred_element_type=F32)
        h = jax.nn.silu(g) * h
    elif cfg.mlp_type == "geglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"],
                       preferred_element_type=F32)
        h = jax.nn.gelu(g, approximate=True) * h
    elif cfg.mlp_type == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jax.nn.gelu(h, approximate=True)
    h = constrain(h.astype(x.dtype), BATCH, None, MODEL)
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"],
                      preferred_element_type=F32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ArchConfig, dtype) -> dict:
    v, d = cfg.padded_vocab, cfg.d_model
    p = {"tok": random.normal(key, (v, d), dtype)}
    if not cfg.tie_embeddings:
        p["head"] = random.normal(random.fold_in(key, 1), (d, v), dtype) * d ** -0.5
    return p


def spec_embed(cfg: ArchConfig, fsdp: Optional[str]) -> dict:
    p = {"tok": (MODEL, fsdp)}
    if not cfg.tie_embeddings:
        p["head"] = (fsdp, MODEL)
    return p


def embed(p, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    return constrain(x, BATCH, None, None)


def unembed(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=F32)
    return constrain(logits, BATCH, None, MODEL)

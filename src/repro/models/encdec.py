"""Encoder-decoder backbone (Whisper-style) with a stub audio frontend.

Per the brief, the conv frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, D).  Positions are absolute
sinusoidal (parameter-free; Whisper's learned decoder table is replaced so
arbitrary decode lengths lower cleanly — deviation noted in DESIGN.md).
The encoder self-attention is bidirectional; the decoder interleaves causal
self-attention and cross-attention to the encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import random

from repro.configs.base import ArchConfig
from repro.distributed.meshctx import BATCH, MODEL, constrain
from repro.models import layers as L

F32 = jnp.float32
ENC_FRAMES = 1500        # Whisper 30 s @ 50 Hz after the conv stub


def _init_xattn(key, cfg: ArchConfig, dtype) -> dict:
    # cross-attention: full MHA (Whisper kv == q heads)
    return L.init_attention(key, cfg, dtype)


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    kenc, kdec, kemb = random.split(key, 3)
    enc_keys = random.split(kenc, cfg.encoder_layers)
    dec_keys = random.split(kdec, cfg.n_layers)

    def enc_block(k):
        k1, k2 = random.split(k)
        return {"ln1": L.init_norm(cfg, dtype),
                "attn": L.init_attention(k1, cfg, dtype),
                "ln2": L.init_norm(cfg, dtype),
                "mlp": L.init_mlp(k2, cfg, dtype)}

    def dec_block(k):
        k1, k2, k3 = random.split(k, 3)
        return {"ln1": L.init_norm(cfg, dtype),
                "attn": L.init_attention(k1, cfg, dtype),
                "lnx": L.init_norm(cfg, dtype),
                "xattn": _init_xattn(k2, cfg, dtype),
                "ln2": L.init_norm(cfg, dtype),
                "mlp": L.init_mlp(k3, cfg, dtype)}

    return {
        "embed": L.init_embed(kemb, cfg, dtype),
        "enc_blocks": jax.vmap(enc_block)(enc_keys),
        "enc_norm": L.init_norm(cfg, dtype),
        "dec_blocks": jax.vmap(dec_block)(dec_keys),
        "final_norm": L.init_norm(cfg, dtype),
    }


def param_specs(cfg: ArchConfig) -> dict:
    fsdp = "data" if cfg.fsdp else None
    enc = {"ln1": L.spec_norm(cfg), "attn": L.spec_attention(cfg, fsdp),
           "ln2": L.spec_norm(cfg), "mlp": L.spec_mlp(cfg, fsdp)}
    dec = {"ln1": L.spec_norm(cfg), "attn": L.spec_attention(cfg, fsdp),
           "lnx": L.spec_norm(cfg), "xattn": L.spec_attention(cfg, fsdp),
           "ln2": L.spec_norm(cfg), "mlp": L.spec_mlp(cfg, fsdp)}
    stack = lambda t: jax.tree_util.tree_map(
        lambda s: (None,) + tuple(s), t, is_leaf=lambda s: isinstance(s, tuple))
    return {"embed": L.spec_embed(cfg, fsdp),
            "enc_blocks": stack(enc), "enc_norm": L.spec_norm(cfg),
            "dec_blocks": stack(dec), "final_norm": L.spec_norm(cfg)}


def _xattn_fwd(p, x, enc_kv, cfg: ArchConfig, impl: str):
    """Cross-attention: queries from x, keys/values precomputed from encoder."""
    k, v = enc_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=F32).astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    from repro.kernels.flash_attention import ops as fa_ops
    out = fa_ops.attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                           v.swapaxes(1, 2), causal=False,
                           scale=cfg.resolved_head_dim ** -0.5, impl=impl)
    out = out.swapaxes(1, 2)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                      preferred_element_type=F32).astype(x.dtype)


def _enc_kv(p, enc_out, cfg: ArchConfig):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"],
                   preferred_element_type=F32).astype(enc_out.dtype)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"],
                   preferred_element_type=F32).astype(enc_out.dtype)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    return k, v


def encode(params, frames: jax.Array, cfg: ArchConfig, *,
           impl: str = "xla") -> jax.Array:
    """frames: (B, S_enc, D) stub embeddings -> encoder hidden states."""
    pos = L.sinusoidal_positions(frames.shape[1], cfg.d_model)
    x = (frames.astype(F32) + pos[None]).astype(frames.dtype)
    x = constrain(x, BATCH, None, None)

    def body(h, lp):
        h = h + L.attention(lp["attn"], L.norm(lp["ln1"], h, cfg), cfg,
                            causal=False, impl=impl)
        h = h + L.mlp(lp["mlp"], L.norm(lp["ln2"], h, cfg), cfg)
        return h, None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.norm(params["enc_norm"], x, cfg)


def decode_train(params, tokens: jax.Array, enc_out: jax.Array,
                 cfg: ArchConfig, *, impl: str = "xla",
                 return_hidden: bool = False) -> jax.Array:
    """Teacher-forced decoder. tokens: (B, S_dec) -> logits (B, S_dec, V)."""
    x = L.embed(params["embed"], tokens, cfg)
    pos = L.sinusoidal_positions(tokens.shape[1], cfg.d_model)
    x = (x.astype(F32) + pos[None]).astype(x.dtype)

    def body(h, lp):
        h = h + L.attention(lp["attn"], L.norm(lp["ln1"], h, cfg), cfg,
                            causal=True, impl=impl)
        kv = _enc_kv(lp["xattn"], enc_out, cfg)
        h = h + _xattn_fwd(lp["xattn"], L.norm(lp["lnx"], h, cfg), kv, cfg,
                           impl)
        h = h + L.mlp(lp["mlp"], L.norm(lp["ln2"], h, cfg), cfg)
        return h, None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.norm(params["final_norm"], x, cfg)
    if return_hidden:
        return x
    return L.unembed(params["embed"], x, cfg)


def forward_train(params, frames: jax.Array, tokens: jax.Array,
                  cfg: ArchConfig, *, impl: str = "xla",
                  return_hidden: bool = False):
    enc_out = encode(params, frames, cfg, impl=impl)
    out = decode_train(params, tokens, enc_out, cfg, impl=impl,
                       return_hidden=return_hidden)
    return out, jnp.zeros((), F32)


# ---------------------------------------------------------------------------
# decode with self-attn KV cache + precomputed cross K/V
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    h = cfg.n_heads
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype),
        # cross-attention K/V, precomputed from the encoder output once
        "xk": jnp.zeros((cfg.n_layers, batch, ENC_FRAMES, h, hd), dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, ENC_FRAMES, h, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: ArchConfig):
    kvspec = (None,) + L.cache_spec(cfg)
    xspec = (None, BATCH, None, MODEL if cfg.n_heads % 16 == 0 else None, None)
    return {"k": kvspec, "v": kvspec, "xk": xspec, "xv": xspec, "pos": ()}


def precompute_cross_kv(params, enc_out: jax.Array, cfg: ArchConfig):
    def body(_, lp):
        k, v = _enc_kv(lp["xattn"], enc_out, cfg)
        return None, (k, v)
    _, (xk, xv) = jax.lax.scan(body, None, params["dec_blocks"])
    return xk, xv


def decode_step(params, cache, tokens: jax.Array, cfg: ArchConfig):
    """tokens: (B,) -> (logits (B, V), new_cache)."""
    pos = cache["pos"]
    x = L.embed(params["embed"], tokens[:, None], cfg)
    pe = L.sinusoidal_positions(1, cfg.d_model)  # position `pos`: recompute
    half = cfg.d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=F32)
                    * (jnp.log(10000.0) / (half - 1)))
    ang = pos.astype(F32) * freqs
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
    x = (x.astype(F32) + pe).astype(x.dtype)

    def body(h, scanned):
        lp, ck, cv, xk, xv = scanned
        y, ck, cv = L.attention_decode(
            lp["attn"], L.norm(lp["ln1"], h, cfg), ck, cv, pos, cfg)
        h = h + y
        h = h + _xattn_fwd(lp["xattn"], L.norm(lp["lnx"], h, cfg), (xk, xv),
                           cfg, "xla")
        h = h + L.mlp(lp["mlp"], L.norm(lp["ln2"], h, cfg), cfg)
        return h, (ck, cv)

    h, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = L.norm(params["final_norm"], h, cfg)
    logits = L.unembed(params["embed"], h, cfg)[:, 0]
    new_cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)
    return logits, new_cache

"""Deterministic chaos schedules for the wave engine.

A :class:`ChaosConfig` hung on ``EngineConfig.chaos`` perturbs the engine
*inside* its jitted wave loop — the adversarial-scheduler half of the
paper's safety argument.  Block-STM's invariant is that the committed state
is independent of the speculative schedule; the engine's ordinary test
suites only ever observe the one schedule the deterministic BSP loop takes.
Chaos widens the observed schedule space while keeping every run exactly
reproducible:

* every perturbation is a pure function of ``(chaos.seed, wave)`` via
  ``jax.random.fold_in`` — same config, same schedule, bit-for-bit, on
  every MV backend and on every device of a ``shard_map`` mesh (threefry
  is elementwise; no collectives are issued);
* perturbations only fire while ``wave < chaos.horizon``, so every chaos
  schedule eventually hands the loop back to the unperturbed engine and
  convergence (or the guarded degradation fallback) is guaranteed;
* ``chaos=None`` (the default) is STATIC, like ``trace_level=0``: the
  hooks below are never traced and the compiled program is exactly the
  unperturbed engine.

Fault model (each hook documents its soundness argument):

===========================  ===========================================
knob                         perturbation
===========================  ===========================================
``corrupt_values``           XOR garbage into the write-slot VALUES of
                             every non-executed row each wave (aborted
                             rows' ESTIMATE entries included) — proves no
                             stale/estimate value can reach a committed
                             read or the final snapshot.
``p_stall``                  stall a random suffix of the selected wave's
                             lanes (execute a 1..window prefix) — proves
                             progress does not depend on wave shape.
``p_spurious_abort``         fail validation of executed txns above the
                             frontier that would have passed — forced
                             re-execution through the full abort path.
``p_recommit``               fail validation of txns BELOW the frontier —
                             forced re-execution of the committed prefix
                             (the frontier is monotone; soundness holds
                             because a committed-prefix re-execution reads
                             only lower committed rows and reproduces its
                             value set exactly).
``p_defer_validation``       withhold this wave's verdict for a row
                             (neither abort nor commit-eligible) — the
                             BSP analogue of reordering/delaying
                             validation tasks.
===========================  ===========================================
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Per-hook fold_in salts: one independent stream per injection point.
_SALT_VALUES, _SALT_LANES, _SALT_VALIDATE = 0, 1, 2

_PROBS = ("p_stall", "p_spurious_abort", "p_recommit", "p_defer_validation")


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """One deterministic perturbation schedule (static; hashable).

    ``horizon`` bounds the waves that perturb: after it the engine runs
    clean, so any chaos schedule either converges exactly or (if the wave
    budget ran out first) falls into the guarded degradation path — both
    end in the preset-order state.
    """

    seed: int = 0                   # PRNG stream; the whole schedule's key
    horizon: int = 6                # perturb only while wave < horizon
    p_stall: float = 0.5            # P[wave keeps only a random lane prefix]
    p_spurious_abort: float = 0.25  # per executed row above the frontier
    p_recommit: float = 0.1         # per committed row below the frontier
    p_defer_validation: float = 0.2  # per executed row: verdict withheld
    corrupt_values: bool = True     # garbage non-executed rows' write values

    def __post_init__(self):
        if self.horizon < 0:
            raise ValueError(f"horizon={self.horizon}: expected >= 0 waves "
                             f"of perturbation (0 disables every hook)")
        for name in _PROBS:
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p}: expected a probability in "
                                 f"[0, 1]")


def _key(chaos: ChaosConfig, wave: jax.Array, salt: int) -> jax.Array:
    k = jax.random.fold_in(jax.random.PRNGKey(chaos.seed), salt)
    return jax.random.fold_in(k, wave)


def _live(chaos: ChaosConfig, wave: jax.Array) -> jax.Array:
    return wave < chaos.horizon


def perturb_values(state, cfg):
    """Corrupt the write-slot values of every non-executed row (wave start).

    Non-executed rows are exactly the unreachable ones: a never-executed
    row has no index entries, and an aborted row's entries are
    ESTIMATE-marked (readers abort on them, validation compares
    writer/incarnation stamps — never values).  A row's values only become
    observable again via a successful execution, which overwrites the full
    row (``_apply_results``), so the garbage provably cannot reach a
    committed read or the final snapshot — the property the chaos suite
    pins down byte-for-byte.
    """
    ch = cfg.chaos
    if not ch.corrupt_values:
        return state
    vals = state.write_vals
    big = jnp.iinfo(jnp.int32).max
    noise = jax.random.randint(_key(ch, state.wave, _SALT_VALUES),
                               vals.shape, -big // 2, big // 2, jnp.int32)
    if jnp.issubdtype(vals.dtype, jnp.integer):
        garbage = (vals.astype(jnp.int32) ^ noise).astype(vals.dtype)
    else:
        garbage = vals + noise.astype(vals.dtype)
    mask = (~state.executed & _live(ch, state.wave))[:, None]
    return state._replace(write_vals=jnp.where(mask, garbage, vals))


def stall_lanes(state, active_ids, active_mask, cfg):
    """Stall a suffix of the selected wave: keep a random 1..window prefix.

    Applied after ``_select_wave``: stalled lanes are masked back to the
    out-of-bounds fill id, exactly like an undersized wave.  Keeping a
    *prefix* preserves lowest-index-first and always executes at least one
    lane, so progress — and therefore convergence after the horizon — is
    unconditional.
    """
    ch = cfg.chaos
    win = active_ids.shape[0]
    kd, kk = jax.random.split(_key(ch, state.wave, _SALT_LANES))
    stall = jax.random.bernoulli(kd, ch.p_stall) & _live(ch, state.wave)
    keep = jax.random.randint(kk, (), 1, win + 1)
    lane_live = ~stall | (jnp.arange(win) < keep)
    ids = jnp.where(lane_live, active_ids, cfg.n_txns).astype(jnp.int32)
    return ids, active_mask & lane_live


def validation_perturb(state, cfg):
    """Per-row validation perturbations: ``(extra_fail, defer)`` masks.

    ``extra_fail`` rows are aborted exactly as a genuine validation
    failure (estimate flip, region bump, re-execution) — above the
    frontier these are spurious aborts, below it forced re-execution of
    the committed prefix.  ``defer`` rows get NO verdict this wave:
    neither aborted nor commit-eligible, and (crucially) their recorded
    read-region versions are NOT refreshed, so a deferred genuine failure
    is still caught by a later wave's validation.  The two masks are
    disjoint by construction.
    """
    ch = cfg.chaos
    n = state.executed.shape[0]
    ka, kr, kd = jax.random.split(_key(ch, state.wave, _SALT_VALIDATE), 3)
    live = _live(ch, state.wave)
    below = jnp.arange(n, dtype=jnp.int32) < state.frontier
    spurious = jax.random.bernoulli(ka, ch.p_spurious_abort, (n,)) & ~below
    recommit = jax.random.bernoulli(kr, ch.p_recommit, (n,)) & below
    extra = (spurious | recommit) & state.executed & live
    defer = (jax.random.bernoulli(kd, ch.p_defer_validation, (n,))
             & state.executed & live & ~extra)
    return extra, defer

"""Robustness layer: chaos schedules, in-jit invariants, guarded degradation.

Block-STM's central safety claim (paper §1, §4) is that *any* speculative
schedule — however adversarial the interleaving of executions, aborts, and
validations — converges to the byte-identical preset-order outcome.  The
engine's conformance suites only ever witness the one schedule the engine
happens to take; this package makes the claim adversarially testable and
the engine's liveness unconditional:

* :mod:`repro.guard.chaos`      — :class:`~repro.guard.chaos.ChaosConfig`,
  a PRNG-keyed, fully deterministic perturbation schedule injected inside
  the wave loop (spurious validation aborts, committed-prefix re-execution,
  stalled lanes, deferred validation verdicts, corrupted estimate values).
  ``EngineConfig.chaos=None`` (default) is static like ``trace_level=0``:
  the perturbation hooks are never traced.
* :mod:`repro.guard.invariants` — :class:`~repro.guard.invariants
  .GuardReport`, in-jit invariant accumulation behind the static
  ``EngineConfig.guard_level`` (no host callbacks; level 0 compiles to the
  exact unguarded program).
* :mod:`repro.guard.degrade`    — the deterministic in-jit sequential
  executor the engine ``lax.cond``s into when the wave loop exhausts
  ``waves_cap`` without converging, so every block commits
  (``BlockResult.degraded``) unless the block is unsound even sequentially.

See README.md in this package for the fault model, the invariant catalog,
and the degradation semantics; ``tests/test_guard.py`` is the property
suite.
"""
from __future__ import annotations

from repro.guard.chaos import ChaosConfig
from repro.guard.invariants import (INVARIANTS, GuardReport, assert_clean,
                                    init_report, summarize)

__all__ = ["ChaosConfig", "GuardReport", "INVARIANTS", "init_report",
           "summarize", "assert_clean"]

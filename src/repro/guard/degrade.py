"""Guarded degradation: the in-jit deterministic sequential executor.

When the wave loop exhausts ``waves_cap`` with ``frontier < n_txns`` the
engine used to return ``committed=False`` and a partial snapshot — a
liveness cliff that ``run_chain`` then fed to the next block.  With
``EngineConfig.degrade_on_stall`` (the default) the engine instead
``lax.cond``s into :func:`sequential_block`: the preset-order sequential
execution of the whole block as a single ``lax.scan``, entirely in-jit
(the host-side oracle ``repro.core.vm.run_sequential`` is numpy and cannot
be called from a traced program).

Semantics: by the paper's correctness claim the sequential state IS the
state every converged speculative schedule commits, so a degraded block is
byte-identical to the block that would have committed with a larger wave
budget — only slower.  ``BlockResult.degraded`` records that the fallback
ran.

The one exception is a block that cannot execute soundly at all (a txn
overflowing its read/write slot budget blocks even sequentially — the
bytecode interpreter raises its ``blocked`` flag with the txn as its own
blocker).  Such a block must NOT commit garbage: :func:`sequential_block`
returns a ``clean`` flag that is False if any txn blocked, and the engine
keeps ``committed=False`` with the partial speculative snapshot in that
case (``tests/test_bytecode.py::test_slot_overflow_fails_loudly``).

Multi-device: the scan is pure elementwise/replicated work (no
collectives), so under the dist engine every device computes the identical
fallback and the replicated-state argument is untouched.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.types import NO_LOC, STORAGE


def sequential_block(program, params: Any, storage: jax.Array, cfg):
    """Execute the block in preset order; return ``(snapshot, clean)``.

    ``snapshot`` is the ``(n_locs,)`` final state vector (same dtype rule
    as the engine's MV snapshot: ``result_type(value_dtype, storage)``);
    ``clean`` is a () bool, False iff some txn blocked (slot overflow).
    Jit-compatible; O(n_txns) scan steps of one VM execution each.
    """
    from repro.core import mv
    from repro.core.vm import make_exec_one
    n, n_locs, w = cfg.n_txns, cfg.n_locs, cfg.max_writes
    out_dtype = jnp.result_type(cfg.value_dtype, storage.dtype)

    # Sequential reads never resolve through the MV index: every read of
    # txn i sees the state vector after txns < i, i.e. resolver always
    # misses and the value reader serves the evolving vector directly.
    miss = mv.ReadResolution(
        found=jnp.asarray(False), writer=jnp.asarray(STORAGE, jnp.int32),
        slot=jnp.asarray(0, jnp.int32), inc=jnp.asarray(-1, jnp.int32),
        is_estimate=jnp.asarray(False))

    def step(carry, xs):
        vec, clean = carry
        txn_idx, p = xs

        def value_reader(res, loc):
            # Same NO_LOC contract as mv.resolve_value: disabled reads
            # clip to location 0 and the VM discards the garbage value.
            return vec[jnp.clip(loc, 0, n_locs - 1)]

        res = make_exec_one(program, cfg, lambda loc, reader: miss,
                            value_reader)(txn_idx, p)
        ok = ~res.blocked
        for s in range(w):
            # Per-slot scalar scatter; dead/blocked slots target n_locs
            # and drop (NO_LOC is negative — never index with it, JAX
            # wraps negatives).  Later slots overwrite earlier ones,
            # matching the VM's latest-write-wins slot order.
            tgt = jnp.where(ok & (res.write_locs[s] != NO_LOC),
                            res.write_locs[s], n_locs)
            vec = vec.at[tgt].set(res.write_vals[s].astype(out_dtype),
                                  mode="drop")
        return (vec, clean & ok), None

    ids = jnp.arange(n, dtype=jnp.int32)
    init = (storage.astype(out_dtype), jnp.asarray(True))
    (vec, clean), _ = jax.lax.scan(step, init, (ids, params))
    return vec, clean

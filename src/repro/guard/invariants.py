"""In-jit engine invariants: a GuardReport accumulated inside the wave loop.

The engine's safety rests on a handful of structural invariants (frontier
monotonicity, incarnation bounds, index-occupancy conservation, the
dirty-validation skip's exactness).  They are argued in docstrings and
property-tested from the outside; this module checks them *inside* the
jitted loop, on every wave, of every run — including chaos-perturbed and
multi-device ones — with no host callbacks.

``EngineConfig.guard_level`` is STATIC, like ``trace_level``:

* level 0 (default): :func:`init_report` returns ``None`` and the engine
  never calls a check — the compiled program is exactly the unguarded one.
* level 1: O(n) per-wave checks — frontier monotonicity, incarnation
  bounds, the backend's structural index health
  (``MVBackend.guard_index_ok``: CSR occupancy/monotonicity for the
  sharded layouts).
* level 2: level 1 + the expensive adversarial checks — recorded read
  locations inside the universe (the precondition that makes the routed
  resolve's owner bucketing non-overflowing by construction) and
  dirty-skip soundness (a full validation pass shadows the skip each wave
  to prove no provably-clean row would actually fail).

The report rides ``EngineState.guard`` (a ``None`` pytree node at level 0)
and returns in ``BlockResult.guard``.  Under the dist engine each device
accumulates its own report (the index check is device-local);
:func:`merge_device_reports` folds them as the block exits the shard_map.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import NO_LOC

#: Invariant catalog, in GuardReport vector order.
INVARIANTS = ("frontier_monotone", "incarnation_bound", "index_occupancy",
              "reads_in_universe", "dirty_skip_sound")

#: guard_level at which each invariant starts being checked.
LEVELS = (1, 1, 1, 2, 2)

_NEVER = -1
_I32_MAX = jnp.iinfo(jnp.int32).max


class GuardReport(NamedTuple):
    """Violation accumulator (shapes: K = ``len(INVARIANTS)``)."""

    violations: jax.Array   # (K,) i32 total violations per invariant
    first_wave: jax.Array   # (K,) i32 first offending wave, -1 = never


def init_report(cfg) -> GuardReport | None:
    """Fresh report for one block (``None`` at guard level 0)."""
    if cfg.guard_level <= 0:
        return None
    k = len(INVARIANTS)
    return GuardReport(violations=jnp.zeros((k,), jnp.int32),
                       first_wave=jnp.full((k,), _NEVER, jnp.int32))


def _record(rep: GuardReport, idx: int, count, wave) -> GuardReport:
    count = jnp.asarray(count).astype(jnp.int32)
    hit = (count > 0) & (rep.first_wave[idx] == _NEVER)
    return GuardReport(
        violations=rep.violations.at[idx].add(count),
        first_wave=rep.first_wave.at[idx].set(
            jnp.where(hit, wave.astype(jnp.int32), rep.first_wave[idx])))


def check_wave(state, cfg, new_frontier, skip_viol=None):
    """Fold one wave's invariant checks into ``state.guard``.

    Called from the tail of the engine's validation phase (before the
    frontier is replaced), so ``state.frontier`` is the pre-wave value and
    ``new_frontier`` the post-wave one.  ``skip_viol`` is the validation
    phase's dirty-skip shadow count (level 2 on the skip path; ``None``
    otherwise).
    """
    from repro.core import mv
    rep = state.guard
    w = state.wave
    # 1. The commit frontier never retreats (committed txns stay committed).
    rep = _record(rep, 0, new_frontier < state.frontier, w)
    # 2. A txn executes at most once per wave: 0 <= incarnation <= wave+1.
    inc_bad = (state.incarnation < 0) | (state.incarnation > w + 1)
    rep = _record(rep, 1, inc_bad.sum(dtype=jnp.int32), w)
    # 3. Backend structural health (CSR occupancy == live write slots, ...).
    ok = mv.make_backend(cfg).guard_index_ok(state.index, state.write_locs)
    rep = _record(rep, 2, ~ok, w)
    if cfg.guard_level >= 2:
        # 4. Every recorded live read location lies inside the universe —
        #    the precondition under which region_of/owner bucketing (and
        #    with it the routed resolve's capacity argument) is total.
        live = state.read_locs != NO_LOC
        oob = live & ((state.read_locs < 0)
                      | (state.read_locs >= cfg.n_locs))
        rep = _record(rep, 3, oob.sum(dtype=jnp.int32), w)
        if skip_viol is not None:
            # 5. Dirty-skip soundness: no version-clean row would fail a
            #    full validation pass (computed in engine._validate_dirty).
            rep = _record(rep, 4, skip_viol, w)
    return state._replace(guard=rep)


def merge_device_reports(rep: GuardReport, axis_name: str) -> GuardReport:
    """Fold per-device reports into one (dist engine, inside shard_map).

    All checks except the index one are functions of the replicated
    scheduler state, so the max over devices is exact for them; the index
    check is device-local, and a violation anywhere is a violation.
    ``first_wave`` takes the earliest wave any device saw (the ``-1``
    never-sentinel maps through INT32_MAX so it loses to any real wave).
    """
    viol = jax.lax.pmax(rep.violations, axis_name)
    fw = jnp.where(rep.first_wave == _NEVER, _I32_MAX, rep.first_wave)
    fw = jax.lax.pmin(fw, axis_name)
    return GuardReport(violations=viol,
                       first_wave=jnp.where(fw == _I32_MAX, _NEVER, fw))


def summarize(rep: GuardReport) -> dict:
    """Host-side view: ``{invariant: {violations, first_wave}}``."""
    import numpy as np
    v = np.asarray(rep.violations)
    fw = np.asarray(rep.first_wave)
    return {name: {"violations": int(v[i]), "first_wave": int(fw[i])}
            for i, name in enumerate(INVARIANTS)}


def assert_clean(rep: GuardReport, context: str = "") -> None:
    """Raise AssertionError if any invariant was violated (host-side)."""
    bad = {k: d for k, d in summarize(rep).items() if d["violations"]}
    if bad:
        where = f" [{context}]" if context else ""
        raise AssertionError(f"engine invariant violations{where}: {bad}")

"""Wave-table / abort-chain CLI over a serialized wave trace.

Renders the ``wave-trace JSON`` written by :mod:`repro.obs.export` (e.g.
``WAVE_TRACE.json`` from ``benchmarks/engine_bench --trace``, or
``make report``) as:

* a per-wave table — frontier, wave size, exec/abort decomposition,
  validation skip hits/misses, MV occupancy;
* the per-device load-balance spread when the trace came from the dist
  engine (``devices > 1``);
* an abort-chain digest (level-2 traces only): the top ESTIMATE writers by
  how many dep-aborts they caused, and the deepest blocking chains — edges
  always point to lower txn ids (preset order), so the edge set is a DAG
  and chain depth is exact, not heuristic.

    PYTHONPATH=src python -m repro.obs.report WAVE_TRACE.json --chains 5
"""
from __future__ import annotations

import sys
from typing import Mapping

import numpy as np

from repro.obs.export import load_wave_trace

_COLS = ("wave", "frontier", "size", "execs", "dep_ab", "val_ab",
         "skip_hit", "skip_miss", "fb", "mv", "dirty")


def wave_table(d: Mapping, max_rows: int = 0) -> str:
    """The per-wave counter table as aligned text."""
    waves = int(d["waves"])
    mv = np.asarray(d["mv_entries"]).sum(axis=0)
    dirty = np.asarray(d["dirty_regions"]).sum(axis=0)
    rows = [_COLS]
    shown = waves if max_rows <= 0 else min(waves, max_rows)
    for w in range(shown):
        rows.append((w, int(d["frontier"][w]), int(d["wave_size"][w]),
                     int(d["execs"][w]), int(d["dep_aborts"][w]),
                     int(d["val_aborts"][w]), int(d["skip_hits"][w]),
                     int(d["skip_misses"][w]),
                     "*" if d["skip_fallback"][w] else "",
                     int(mv[w]), int(dirty[w])))
    widths = [max(len(str(r[i])) for r in rows) for i in range(len(_COLS))]
    lines = ["  ".join(str(c).rjust(widths[i]) for i, c in enumerate(r))
             for r in rows]
    if shown < waves:
        lines.append(f"... ({waves - shown} more waves)")
    return "\n".join(lines)


def summary(d: Mapping) -> str:
    waves = int(d["waves"])
    ex = int(np.sum(d["execs"]))
    da = int(np.sum(d["dep_aborts"]))
    va = int(np.sum(d["val_aborts"]))
    frontier = int(d["frontier"][waves - 1]) if waves else 0
    lines = [f"waves={waves} frontier={frontier} execs={ex} "
             f"dep_aborts={da} val_aborts={va} "
             f"wasted={(da + va) / max(ex + da, 1):.1%}"]
    dev = int(d.get("devices", 1))
    if dev > 1:
        mv = np.asarray(d["mv_entries"])          # (D, waves)
        tot = mv[:, waves - 1] if waves else mv.sum(axis=1)
        lines.append(
            f"devices={dev} final mv entries/device "
            f"min={int(tot.min())} max={int(tot.max())} "
            f"imbalance={tot.max() / max(tot.min(), 1):.2f}x")
        lanes = np.asarray(d["exec_lanes"])[:, :waves]  # (D, waves)
        per_dev = lanes.sum(axis=1)
        lines.append(
            f"exec lanes/device min={int(per_dev.min())} "
            f"max={int(per_dev.max())} "
            f"(wave partition; total={int(per_dev.sum())})")
    return "\n".join(lines)


def _edge_counts(d: Mapping) -> dict[int, dict[int, int]]:
    """blocked txn -> {blocker: times seen} across all waves."""
    edges: dict[int, dict[int, int]] = {}
    for wave_edges in d.get("abort_edges", []):
        for blocked, blocker in wave_edges:
            edges.setdefault(blocked, {})
            edges[blocked][blocker] = edges[blocked].get(blocker, 0) + 1
    return edges


def abort_chains(d: Mapping, top: int = 5) -> str:
    """Top blockers + deepest blocking chains from the level-2 edges."""
    if "abort_edges" not in d:
        return ("no abort edges in trace (recorded at trace_level >= 2 "
                "only)")
    edges = _edge_counts(d)
    if not edges:
        return "no dep-aborts recorded"
    caused: dict[int, int] = {}
    for blockers in edges.values():
        for blocker, n in blockers.items():
            caused[blocker] = caused.get(blocker, 0) + n
    top_blockers = sorted(caused.items(), key=lambda kv: -kv[1])[:top]
    lines = ["top blockers (txn: dep-aborts caused): "
             + "  ".join(f"{t}:{n}" for t, n in top_blockers)]

    # Edges respect the preset order (blocker < blocked), so chained waits
    # form a DAG over txn ids; depth via memoized walk toward txn 0.
    depth: dict[int, tuple[int, list[int]]] = {}

    def walk(t: int) -> tuple[int, list[int]]:
        if t in depth:
            return depth[t]
        if t not in edges:
            depth[t] = (0, [t])
            return depth[t]
        best = max((walk(b) for b in edges[t]), key=lambda r: r[0])
        depth[t] = (best[0] + 1, [t] + best[1])
        return depth[t]

    chains = sorted((walk(t) for t in edges), key=lambda r: -r[0])[:top]
    lines.append("deepest blocking chains (blocked -> ... -> root):")
    for dep, path in chains:
        lines.append(f"  depth {dep}: " + " -> ".join(map(str, path)))
    return "\n".join(lines)


def render(d: Mapping, max_rows: int = 0, chains: int = 5) -> str:
    return "\n".join([summary(d), "", wave_table(d, max_rows=max_rows), "",
                      abort_chains(d, top=chains)])


def main(argv: list[str] | None = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="WAVE_TRACE.json",
                    help="wave-trace JSON (default: WAVE_TRACE.json)")
    ap.add_argument("--rows", type=int, default=0,
                    help="max wave rows to print (0 = all)")
    ap.add_argument("--chains", type=int, default=5,
                    help="abort chains / top blockers to show")
    args = ap.parse_args(argv)
    try:
        d = load_wave_trace(args.path)
    except FileNotFoundError:
        sys.exit(f"{args.path} not found — generate one with "
                 f"`PYTHONPATH=src python -m benchmarks.engine_bench "
                 f"--workload mixed --trace`")
    print(render(d, max_rows=args.rows, chains=args.chains))


if __name__ == "__main__":
    main()

"""Wave-table / abort-chain / perf-history CLI.

Renders the ``wave-trace JSON`` written by :mod:`repro.obs.export` (e.g.
``WAVE_TRACE.json`` from ``benchmarks/engine_bench --trace``, or
``make report``) as:

* a per-wave table — frontier, wave size, exec/abort decomposition,
  validation skip hits/misses, MV occupancy;
* the per-device load-balance spread when the trace came from the dist
  engine (``devices > 1``);
* an abort-chain digest (level-2 traces only): the top ESTIMATE writers by
  how many dep-aborts they caused, and the deepest blocking chains — edges
  always point to lower txn ids (preset order), so the edge set is a DAG
  and chain depth is exact, not heuristic.

``--history`` (``make dashboard``) instead renders the commit-stamped
perf trajectory ``BENCH_HISTORY.jsonl`` (appended by every
``benchmarks.registry`` suite run) as one cross-commit trend table per
suite.  The lines carry flat pre-extracted headline metrics, so this
module needs only the file — not the benchmark registry (src never
imports benchmarks).

    PYTHONPATH=src python -m repro.obs.report WAVE_TRACE.json --chains 5
    PYTHONPATH=src python -m repro.obs.report --history
"""
from __future__ import annotations

import json
import sys
from typing import Mapping

import numpy as np

from repro.obs.export import load_wave_trace

_COLS = ("wave", "frontier", "size", "execs", "dep_ab", "val_ab",
         "skip_hit", "skip_miss", "fb", "mv", "dirty")


def wave_table(d: Mapping, max_rows: int = 0) -> str:
    """The per-wave counter table as aligned text."""
    waves = int(d["waves"])
    mv = np.asarray(d["mv_entries"]).sum(axis=0)
    dirty = np.asarray(d["dirty_regions"]).sum(axis=0)
    rows = [_COLS]
    shown = waves if max_rows <= 0 else min(waves, max_rows)
    for w in range(shown):
        rows.append((w, int(d["frontier"][w]), int(d["wave_size"][w]),
                     int(d["execs"][w]), int(d["dep_aborts"][w]),
                     int(d["val_aborts"][w]), int(d["skip_hits"][w]),
                     int(d["skip_misses"][w]),
                     "*" if d["skip_fallback"][w] else "",
                     int(mv[w]), int(dirty[w])))
    widths = [max(len(str(r[i])) for r in rows) for i in range(len(_COLS))]
    lines = ["  ".join(str(c).rjust(widths[i]) for i, c in enumerate(r))
             for r in rows]
    if shown < waves:
        lines.append(f"... ({waves - shown} more waves)")
    return "\n".join(lines)


def summary(d: Mapping) -> str:
    waves = int(d["waves"])
    ex = int(np.sum(d["execs"]))
    da = int(np.sum(d["dep_aborts"]))
    va = int(np.sum(d["val_aborts"]))
    frontier = int(d["frontier"][waves - 1]) if waves else 0
    lines = [f"waves={waves} frontier={frontier} execs={ex} "
             f"dep_aborts={da} val_aborts={va} "
             f"wasted={(da + va) / max(ex + da, 1):.1%}"]
    dev = int(d.get("devices", 1))
    if dev > 1:
        mv = np.asarray(d["mv_entries"])          # (D, waves)
        tot = mv[:, waves - 1] if waves else mv.sum(axis=1)
        lines.append(
            f"devices={dev} final mv entries/device "
            f"min={int(tot.min())} max={int(tot.max())} "
            f"imbalance={tot.max() / max(tot.min(), 1):.2f}x")
        lanes = np.asarray(d["exec_lanes"])[:, :waves]  # (D, waves)
        per_dev = lanes.sum(axis=1)
        lines.append(
            f"exec lanes/device min={int(per_dev.min())} "
            f"max={int(per_dev.max())} "
            f"(wave partition; total={int(per_dev.sum())})")
    return "\n".join(lines)


def _edge_counts(d: Mapping) -> dict[int, dict[int, int]]:
    """blocked txn -> {blocker: times seen} across all waves."""
    edges: dict[int, dict[int, int]] = {}
    for wave_edges in d.get("abort_edges", []):
        for blocked, blocker in wave_edges:
            edges.setdefault(blocked, {})
            edges[blocked][blocker] = edges[blocked].get(blocker, 0) + 1
    return edges


def abort_chains(d: Mapping, top: int = 5) -> str:
    """Top blockers + deepest blocking chains from the level-2 edges."""
    if "abort_edges" not in d:
        return ("no abort edges in trace (recorded at trace_level >= 2 "
                "only)")
    edges = _edge_counts(d)
    if not edges:
        return "no dep-aborts recorded"
    caused: dict[int, int] = {}
    for blockers in edges.values():
        for blocker, n in blockers.items():
            caused[blocker] = caused.get(blocker, 0) + n
    top_blockers = sorted(caused.items(), key=lambda kv: -kv[1])[:top]
    lines = ["top blockers (txn: dep-aborts caused): "
             + "  ".join(f"{t}:{n}" for t, n in top_blockers)]

    # Edges respect the preset order (blocker < blocked), so chained waits
    # form a DAG over txn ids; depth via memoized walk toward txn 0.
    depth: dict[int, tuple[int, list[int]]] = {}

    def walk(t: int) -> tuple[int, list[int]]:
        if t in depth:
            return depth[t]
        if t not in edges:
            depth[t] = (0, [t])
            return depth[t]
        best = max((walk(b) for b in edges[t]), key=lambda r: r[0])
        depth[t] = (best[0] + 1, [t] + best[1])
        return depth[t]

    chains = sorted((walk(t) for t in edges), key=lambda r: -r[0])[:top]
    lines.append("deepest blocking chains (blocked -> ... -> root):")
    for dep, path in chains:
        lines.append(f"  depth {dep}: " + " -> ".join(map(str, path)))
    return "\n".join(lines)


def render(d: Mapping, max_rows: int = 0, chains: int = 5) -> str:
    return "\n".join([summary(d), "", wave_table(d, max_rows=max_rows), "",
                      abort_chains(d, top=chains)])


# ---------------------------------------------------------------------------
# Perf-history trend tables (make dashboard)
# ---------------------------------------------------------------------------

#: Default trajectory file (written by benchmarks.registry at the repo
#: root; `make dashboard` runs from there).
HISTORY_DEFAULT = "BENCH_HISTORY.jsonl"


def load_history(path: str = HISTORY_DEFAULT) -> list[dict]:
    """All history lines in append order (skips blank lines)."""
    with open(path) as f:
        return [json.loads(raw) for raw in f if raw.strip()]


def _fmt_metric(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def history_tables(lines: list[dict]) -> str:
    """One cross-commit trend table per suite, rows in append (= commit)
    order.  Metric columns are the union over the suite's lines in first-
    appearance order, so a metric added later shows as ``-`` for older
    rows rather than hiding history."""
    if not lines:
        return ("no history lines — run a suite first "
                "(PYTHONPATH=src python -m benchmarks.registry run --all)")
    by_suite: dict[str, list[dict]] = {}
    for line in lines:
        by_suite.setdefault(str(line.get("suite")), []).append(line)
    out: list[str] = []
    for suite in sorted(by_suite):
        runs = by_suite[suite]
        cols: list[str] = []
        for line in runs:
            for k in line.get("metrics", {}):
                if k not in cols:
                    cols.append(k)
        header = ["sha", "rev", "mode", "platform"] + cols
        rows = [header]
        for line in runs:
            sha = str(line.get("sha", "?"))
            if line.get("dirty"):
                sha += "*"
            m = line.get("metrics", {})
            rows.append([sha, str(line.get("schema_rev", "?")),
                         str(line.get("mode", "?")),
                         str(line.get("platform", "?"))]
                        + [_fmt_metric(m[k]) if k in m else "-"
                           for k in cols])
        widths = [max(len(r[i]) for r in rows) for i in range(len(header))]
        table = "\n".join("  ".join(c.rjust(widths[i])
                                    for i, c in enumerate(r)) for r in rows)
        out.append(f"[{suite}] {len(runs)} run(s)   (* = dirty worktree)\n"
                   f"{table}")
    return "\n\n".join(out)


def main(argv: list[str] | None = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default=None,
                    help="wave-trace JSON (default: WAVE_TRACE.json), or "
                    "the history JSONL with --history (default: "
                    f"{HISTORY_DEFAULT})")
    ap.add_argument("--rows", type=int, default=0,
                    help="max wave rows to print (0 = all)")
    ap.add_argument("--chains", type=int, default=5,
                    help="abort chains / top blockers to show")
    ap.add_argument("--history", action="store_true",
                    help="render the commit-stamped benchmark trajectory "
                    "as cross-commit trend tables (make dashboard)")
    args = ap.parse_args(argv)
    if args.history:
        path = args.path or HISTORY_DEFAULT
        try:
            lines = load_history(path)
        except FileNotFoundError:
            sys.exit(f"{path} not found — run a registry suite first "
                     f"(PYTHONPATH=src python -m benchmarks.registry "
                     f"run --all)")
        print(history_tables(lines))
        return
    path = args.path or "WAVE_TRACE.json"
    try:
        d = load_wave_trace(path)
    except FileNotFoundError:
        sys.exit(f"{path} not found — generate one with "
                 f"`PYTHONPATH=src python -m benchmarks.engine_bench "
                 f"--workload mixed --trace`")
    print(render(d, max_rows=args.rows, chains=args.chains))


if __name__ == "__main__":
    main()

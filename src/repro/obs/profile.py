"""Host-side profiling spans: perfetto traces of the jitted engine.

Two layers, complementary to the in-jit :mod:`repro.obs.trace` buffers:

* The engine's phase functions are wrapped in ``jax.named_scope`` (see
  ``core/engine.py::_named_phase``) — zero-runtime-cost HLO metadata, so
  ``blockstm.execute`` / ``blockstm.index`` / ``blockstm.validate`` /
  ``blockstm.snapshot`` label the compiled ops in ANY profiler view.
* :func:`profile_block` wraps a region in ``jax.profiler.trace``, emitting a
  perfetto ``.trace.json.gz`` under the chosen directory — open it at
  https://ui.perfetto.dev (or ``chrome://tracing``) and the named scopes
  above appear as spans inside the XLA executable.

``make profile`` runs this module's CLI: one representative mixed-contract
block (compile excluded — the block runs once to warm before the traced
repetitions) profiled into ``profiles/``.

    PYTHONPATH=src python -m repro.obs.profile --out profiles --reps 3
"""
from __future__ import annotations

import contextlib
import os
from typing import Iterator

import jax

#: Name prefix shared by the engine's phase scopes (core/engine.py).
PHASE_SCOPE_PREFIX = "blockstm."


@contextlib.contextmanager
def profile_block(logdir: str) -> Iterator[str]:
    """Profile everything inside the ``with`` into a perfetto trace.

    Thin, exception-safe wrapper over ``jax.profiler.trace``: creates
    ``logdir``, runs the profiler around the body, and yields the logdir so
    call sites can report where the ``plugins/profile/*/ *.trace.json.gz``
    dump landed.  Host wall-time spans can be added inside the body with
    ``jax.profiler.TraceAnnotation`` / :func:`annotate`.
    """
    os.makedirs(logdir, exist_ok=True)
    with jax.profiler.trace(logdir):
        yield logdir


def annotate(name: str):
    """A host wall-time span visible in the perfetto timeline.

    Alias for ``jax.profiler.TraceAnnotation`` so benchmark code only
    imports ``repro.obs``.  Use around host-side block boundaries (e.g. one
    annotation per timed rep) — device-side phase structure already comes
    from the engine's named scopes.
    """
    return jax.profiler.TraceAnnotation(name)


def _profile_mixed_block(out: str, n_txns: int, reps: int) -> str:
    """CLI body: profile ``reps`` executions of one mixed block."""
    from repro.core import workloads as W
    from repro.core.engine import make_executor

    vm, params, storage, cfg = W.make_mixed_block(W.MixedSpec(), n_txns,
                                                  seed=0)
    run = make_executor(vm, cfg)
    run(params, storage).snapshot.block_until_ready()   # compile + warm
    with profile_block(out) as logdir:
        for r in range(reps):
            with annotate(f"block[{r}]"):
                run(params, storage).snapshot.block_until_ready()
    return logdir


def main(argv: list[str] | None = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="profiles",
                    help="profiler log directory (default: profiles/)")
    ap.add_argument("--n-txns", type=int, default=512)
    ap.add_argument("--reps", type=int, default=3,
                    help="post-warmup executions to capture")
    args = ap.parse_args(argv)
    logdir = _profile_mixed_block(args.out, args.n_txns, args.reps)
    print(f"perfetto trace written under {logdir}/ "
          f"(open the .trace.json.gz at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()

"""Wave-trace serialization + Chrome-trace (perfetto-loadable) exporter.

Two output formats for one :class:`~repro.obs.trace.WaveTrace`:

* ``wave-trace JSON`` — :func:`trace_to_dict` / :func:`write_wave_trace`:
  the raw per-wave buffers, trimmed to the block's actual wave count, with
  a schema tag and the level-2 abort edges compressed to live
  ``[blocked, blocker]`` pairs.  :func:`load_wave_trace` round-trips it
  back to numpy arrays (property-tested in ``tests/test_obs.py``);
  ``repro.obs.report`` renders it as a wave table / abort-chain digest.
* ``Chrome trace JSON`` — :func:`to_chrome_trace` /
  :func:`write_chrome_trace`: the ``traceEvents`` array format that
  https://ui.perfetto.dev and ``chrome://tracing`` load directly.  Each
  wave becomes a complete ("X") event whose args carry its counters, and
  every scalar counter additionally streams as a counter ("C") track, so
  frontier convergence / abort bursts / MV-index growth are visible as
  plots over the wave axis.

Timebase: the in-jit buffers carry no wall-clock (a wave is one iteration
of a fused ``lax.while_loop``), so by default the exporter lays waves on a
VIRTUAL microsecond axis where each wave's width is its ``wave_size`` —
span width ∝ attempted lanes.  Pass ``phase_times`` (per-wave
execute/index/validate wall-clock seconds, e.g. from
``benchmarks/hotpath_bench.py``'s phase replay) to switch the axis to real
time and emit per-phase sub-spans on their own track.
"""
from __future__ import annotations

import json
from typing import Any, Mapping, Sequence

import numpy as np

from repro.obs.trace import NO_TXN, WaveTrace

#: Schema tag stamped into every serialized trace (bump on layout change).
#: v3: + ``frontier_stall`` counter and the block-level ``degraded`` flag.
SCHEMA = "blockstm-wave-trace/v3"

#: The scalar counter fields, in serialization order.
COUNTER_FIELDS = ("frontier", "wave_size", "execs", "dep_aborts",
                  "val_aborts", "exec_reads", "val_reads", "skip_hits",
                  "skip_misses", "skip_fallback", "frontier_stall")

#: Per-device fields — ``(cap,)`` single-device, ``(D, cap)`` after the
#: dist merge; serialized with an explicit device axis either way.
DEVICE_FIELDS = ("dirty_regions", "mv_entries", "exec_lanes")

PHASES = ("execute", "index", "validate")


def trace_to_dict(trace: WaveTrace, waves: Any,
                  meta: Mapping[str, Any] | None = None) -> dict:
    """Serialize a trace to a plain-JSON dict, trimmed to ``waves`` rows."""
    w = int(waves)
    out: dict[str, Any] = {"schema": SCHEMA, "waves": w,
                           "meta": dict(meta or {})}
    for f in COUNTER_FIELDS:
        out[f] = np.asarray(getattr(trace, f))[:w].astype(int).tolist()
    for f in DEVICE_FIELDS:
        a = np.asarray(getattr(trace, f))
        a = a[None, :] if a.ndim == 1 else a       # -> (D, cap) either way
        out[f] = a[:, :w].astype(int).tolist()
    out["devices"] = len(out[DEVICE_FIELDS[0]])
    degraded = getattr(trace, "degraded", None)
    out["degraded"] = bool(np.asarray(degraded)) if degraded is not None \
        else False
    if trace.blocked_ids is not None:
        bi = np.asarray(trace.blocked_ids)[:w]
        bl = np.asarray(trace.blockers)[:w]
        out["abort_edges"] = [
            [[int(b), int(k)] for b, k in zip(bi[i], bl[i]) if b != NO_TXN]
            for i in range(w)]
    return out


def write_wave_trace(path: str, trace: WaveTrace, waves: Any,
                     meta: Mapping[str, Any] | None = None) -> dict:
    d = trace_to_dict(trace, waves, meta=meta)
    with open(path, "w") as f:
        json.dump(d, f, indent=1, sort_keys=True)
        f.write("\n")
    return d


def load_wave_trace(path: str) -> dict:
    """Load a serialized trace; counters come back as numpy int arrays."""
    with open(path) as f:
        d = json.load(f)
    if d.get("schema") != SCHEMA:
        raise ValueError(f"{path}: schema {d.get('schema')!r}, "
                         f"expected {SCHEMA!r}")
    for f_ in COUNTER_FIELDS + DEVICE_FIELDS:
        d[f_] = np.asarray(d[f_], dtype=np.int64)
    return d


def _counter_sum(d: Mapping[str, Any], field: str) -> np.ndarray:
    """A device field as one global per-wave series (sum over devices)."""
    return np.asarray(d[field]).sum(axis=0)


def to_chrome_trace(d: Mapping[str, Any],
                    phase_times: Sequence[Mapping[str, float]] | None = None,
                    ) -> dict:
    """Render a :func:`trace_to_dict` payload as Chrome trace events.

    ``phase_times`` (optional): one mapping per wave with wall-clock
    seconds for each of :data:`PHASES` — switches the time axis from the
    virtual wave_size-proportional layout to real microseconds and adds a
    per-phase span track.
    """
    waves = int(d["waves"])
    pid = 0
    ev: list[dict] = [
        {"ph": "M", "pid": pid, "name": "process_name",
         "args": {"name": "blockstm"}},
        {"ph": "M", "pid": pid, "tid": 0, "name": "thread_name",
         "args": {"name": "waves"}},
    ]
    if phase_times is not None:
        ev.append({"ph": "M", "pid": pid, "tid": 1, "name": "thread_name",
                   "args": {"name": "phases (wall-clock)"}})

    dirty = _counter_sum(d, "dirty_regions")
    mv = _counter_sum(d, "mv_entries")
    ts = 0.0
    for w in range(waves):
        if phase_times is not None:
            dur = sum(float(phase_times[w].get(p, 0.0)) * 1e6
                      for p in PHASES)
        else:
            dur = float(max(int(d["wave_size"][w]), 1))
        args = {f: int(d[f][w]) for f in COUNTER_FIELDS}
        args["dirty_regions"] = int(dirty[w])
        args["mv_entries"] = int(mv[w])
        ev.append({"ph": "X", "pid": pid, "tid": 0, "name": f"wave {w}",
                   "ts": ts, "dur": dur, "args": args})
        if phase_times is not None:
            pts = ts
            for p in PHASES:
                pdur = float(phase_times[w].get(p, 0.0)) * 1e6
                ev.append({"ph": "X", "pid": pid, "tid": 1, "name": p,
                           "ts": pts, "dur": pdur, "args": {"wave": w}})
                pts += pdur
        for name, series in (
                ("frontier", d["frontier"]), ("execs", d["execs"]),
                ("dep_aborts", d["dep_aborts"]),
                ("val_aborts", d["val_aborts"]),
                ("mv_entries", mv), ("dirty_regions", dirty)):
            ev.append({"ph": "C", "pid": pid, "name": name, "ts": ts,
                       "args": {name: int(series[w])}})
        ts += dur
    return {"traceEvents": ev, "displayTimeUnit": "ms",
            "otherData": {"schema": d.get("schema", SCHEMA),
                          "waves": waves,
                          "devices": int(d.get("devices", 1)),
                          "degraded": bool(d.get("degraded", False)),
                          "timebase": ("wall_clock" if phase_times
                                       else "virtual_wave_size"),
                          **dict(d.get("meta", {}))}}


def write_chrome_trace(path: str, d: Mapping[str, Any],
                       phase_times: Sequence[Mapping[str, float]] | None
                       = None) -> dict:
    ct = to_chrome_trace(d, phase_times=phase_times)
    with open(path, "w") as f:
        json.dump(ct, f, indent=1, sort_keys=True)
        f.write("\n")
    return ct

"""Compiled-artifact cost accounting for the engine's phases.

The wave loop has been *timed* since PR 6; this module *accounts* it:
FLOPs, HBM traffic, and collective bytes read off the compiled XLA
artifact, plus the compiler's own memory analysis and a jit-cache-miss
counter.  Three sources:

* :func:`jit_cost` / :func:`compiled_cost` — ``fn.lower(*args).compile()``
  walked by the trip-count-aware HLO walker
  (:mod:`repro.launch.hlo_analysis`), which multiplies ``while`` bodies by
  their ``known_trip_count`` — ``compiled.cost_analysis()`` counts every
  loop body ONCE, so the bytecode interpreter's ``lax.scan`` (and the wave
  ``while_loop`` when a whole block executor is lowered) would be
  undercounted by the trip count without it.  ``memory_analysis()``
  argument/output/temp sizes ride along.
* :func:`routed_exchange_stats` / :func:`crosscheck_routed_read_bytes` —
  the dist execute phase's collective accounting.  Each routed read site
  compiles to exactly :data:`A2A_ARRAYS_PER_EXCHANGE` ``all-to-all`` ops
  (2 query-leg arrays: loc + reader, both i32; 5 answer-leg arrays: the
  ``ReadResolution`` found/writer/slot/incarnation/is_estimate fields), so
  the walker's all-to-all totals decompose exactly into
  ``n_exchanges x devices x lanes_per_device x 22 B`` — and the
  hand-computed ``routed_read_bytes_per_device`` that ``BENCH_dist.json``
  has carried since PR 7 must equal the HLO-derived per-device bucket
  bytes times ``max_reads``.  The cross-check turns that committed
  constant from an asserted formula into a measured property of the
  compiled artifact.
* :func:`cache_misses` — recompile accounting for a jitted callable, so
  "zero recompiles across mixes" is a gated registry metric
  (``jit_cache_misses == 0``, direction ``exact``) rather than only a
  test-suite assertion.

Everything here runs at trace/compile time — no benchmark execution — so
suites can stamp cost fields into their records for free.
"""
from __future__ import annotations

from typing import Any, Callable, Mapping

from repro.launch.hlo_analysis import COLLECTIVES, aggregate

#: HLO ``all-to-all`` ops emitted per routed read exchange (see
#: :meth:`repro.core.dist.backend.DistShardedBackend._route_chunk`): the
#: query leg routes 2 i32 arrays (loc, reader), the answer leg routes the
#: 5 ``ReadResolution`` fields (found u8, writer i32, slot i32,
#: incarnation i32, is_estimate u8) back.
A2A_ARRAYS_PER_EXCHANGE = 7

#: Live payload bytes one routed read moves end to end: 8 B query out +
#: 14 B ``ReadResolution`` back (the PR 7 ``dist_bench.ROUTED_READ_BYTES``
#: constant, re-derived here from the exchange structure: the 7 routed
#: arrays carry 4+4 query + 1+4+4+4+1 answer bytes per slot).
ROUTED_READ_BYTES = (4 + 4) + (1 + 4 + 4 + 4 + 1)


def compiled_cost(compiled) -> dict:
    """Cost record for one compiled artifact.

    ``flops`` / ``hbm_bytes`` / per-collective bytes+counts come from the
    trip-count-aware HLO walk (per-device quantities in post-SPMD HLO);
    ``memory`` from ``compiled.memory_analysis()`` (argument / output /
    temp / generated-code bytes — ``peak_bytes`` is their live-at-once
    proxy ``args + out + temp``, what the compiler reserves for one
    call)."""
    t = aggregate(compiled.as_text())
    cost = {
        "flops": float(t["flops"]),
        "hbm_bytes": float(t["bytes"]),
        "collective_bytes": float(t["collective_bytes"]),
        "collectives": {k: float(t[k]) for k in COLLECTIVES},
        "collective_counts": {k: int(t[f"n_{k}"]) for k in COLLECTIVES},
    }
    mem = None
    try:
        mem = compiled.memory_analysis()
    except Exception:           # backends without the query keep cost useful
        pass
    if mem is not None:
        args_b = int(getattr(mem, "argument_size_in_bytes", 0) or 0)
        out_b = int(getattr(mem, "output_size_in_bytes", 0) or 0)
        tmp_b = int(getattr(mem, "temp_size_in_bytes", 0) or 0)
        cost["memory"] = {
            "argument_bytes": args_b,
            "output_bytes": out_b,
            "temp_bytes": tmp_b,
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0) or 0),
            "peak_bytes": args_b + out_b + tmp_b,
        }
    return cost


def jit_cost(fn: Callable, *args, **kw) -> dict:
    """Lower+compile a jitted callable and account it (no execution)."""
    return compiled_cost(fn.lower(*args, **kw).compile())


def phase_costs(phases: Mapping[str, tuple]) -> dict[str, dict]:
    """Account several phases at once: ``{name: (jitted_fn, args...)}`` ->
    ``{name: cost_record}`` (the hotpath/dist suites' per-phase tables)."""
    return {name: jit_cost(spec[0], *spec[1:])
            for name, spec in phases.items()}


def cache_misses(fn: Callable, expected_compiles: int = 1) -> int:
    """Recompiles beyond ``expected_compiles`` for a jitted callable.

    ``make_executor``'s contract is compile-once-serve-every-mix; after a
    suite has served all its mixes, ``cache_misses(run) == 0`` is the
    zero-recompile property as a number the regression gate can hold at
    exactly 0.  Returns -1 when the callable exposes no jit cache (a
    non-jitted wrapper) so the gap is visible rather than silently 0."""
    size = getattr(fn, "_cache_size", None)
    if size is None:
        return -1
    return int(size()) - int(expected_compiles)


# ---------------------------------------------------------------------------
# Routed-exchange collective accounting (dist execute phase)
# ---------------------------------------------------------------------------

def routed_exchange_stats(cost: dict, devices: int) -> dict:
    """Decompose an execute-phase cost record's all-to-all totals.

    Returns ``n_exchanges`` (routed read sites x loop trips),
    ``bytes_per_exchange`` (all devices' buckets, both legs), and
    ``bucket_bytes_per_device`` (one device's slot payload per exchange =
    ``lanes_per_device x 22 B``).  Raises ``ValueError`` when the op count
    does not decompose into whole exchanges — the compiled artifact then
    has a different collective structure than the routed resolver emits,
    which is exactly what the cross-check exists to catch."""
    n_ops = int(cost["collective_counts"]["all-to-all"])
    total = float(cost["collectives"]["all-to-all"])
    if n_ops <= 0 or n_ops % A2A_ARRAYS_PER_EXCHANGE:
        raise ValueError(
            f"{n_ops} all-to-all ops do not decompose into "
            f"{A2A_ARRAYS_PER_EXCHANGE}-array routed exchanges")
    n_exchanges = n_ops // A2A_ARRAYS_PER_EXCHANGE
    per_exchange = total / n_exchanges
    return {
        "n_exchanges": n_exchanges,
        "bytes_per_exchange": per_exchange,
        "bucket_bytes_per_device": per_exchange / devices,
    }


def crosscheck_routed_read_bytes(cost: dict, devices: int, max_reads: int,
                                 expected_per_device: int) -> dict:
    """Check the HLO-derived routed payload against the hand-computed one.

    ``expected_per_device`` is ``BENCH_dist.json``'s
    ``routed_read_bytes_per_device`` (``lanes_per_device x max_reads x
    22``).  The HLO side derives the same quantity with no hand formula:
    one exchange's per-device bucket bytes (``lanes x 22``, read off the
    compiled all-to-all shapes) times the ``max_reads`` read sites each
    lane resolves.  Exact integer agreement or ``ValueError`` — a drift
    means the routed exchange's wire format and the committed structural
    record no longer describe the same engine."""
    stats = routed_exchange_stats(cost, devices)
    hlo_derived = stats["bucket_bytes_per_device"] * max_reads
    if round(hlo_derived) != int(expected_per_device):
        raise ValueError(
            f"HLO-derived routed read bytes/device {hlo_derived:.1f} != "
            f"hand-computed {expected_per_device} "
            f"(exchange stats: {stats})")
    return {**stats, "routed_read_bytes_per_device_hlo": int(
        round(hlo_derived))}

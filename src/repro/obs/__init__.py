"""Engine-native observability: in-jit wave telemetry, profiling spans,
trace exporters.

Three layers (see README.md in this package):

* :mod:`repro.obs.trace`   — :class:`~repro.obs.trace.WaveTrace` in-jit
  per-wave ring buffers, recorded by the engine's phase hooks and enabled
  by the static ``EngineConfig.trace_level`` (level 0 = the exact untraced
  program).
* :mod:`repro.obs.profile` — host-side profiling spans
  (``jax.profiler.TraceAnnotation``) and the ``jax.profiler.trace``
  context manager behind ``make profile`` (perfetto-compatible dump).
* :mod:`repro.obs.export` / :mod:`repro.obs.report` — Chrome-trace JSON
  export and the wave-table / abort-chain / perf-history report CLI behind
  ``make report`` / ``make dashboard``.
* :mod:`repro.obs.cost`    — compiled-artifact cost accounting: per-phase
  FLOPs / HBM / collective bytes via the trip-count-aware HLO walker,
  ``memory_analysis()``, the routed-exchange collective cross-check, and
  the jit-cache-miss counter (consumed by the benchmark registry).
"""
from __future__ import annotations

from repro.obs.trace import (NO_TXN, ValTraceAux, WaveTrace, init_trace,
                             merge_device_traces, record_execute,
                             record_index, record_validate)

__all__ = ["NO_TXN", "ValTraceAux", "WaveTrace", "init_trace",
           "merge_device_traces", "record_execute", "record_index",
           "record_validate", "cost", "export", "profile", "report"]


def __getattr__(name):
    # The host-side layers (numpy/profiler imports) load lazily so the
    # engine's in-jit hook path pays only for repro.obs.trace.
    if name in ("cost", "export", "profile", "report"):
        import importlib
        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""In-jit wave trace buffers: the engine's per-wave telemetry substrate.

The Block-STM engine runs as ONE jitted ``lax.while_loop`` — by the time a
block returns, every intermediate the paper's evaluation plots (per-wave
abort counts, the convergence of the commit frontier, which txn chains
forced re-execution) has been consumed by the loop carry.  A
:class:`WaveTrace` is a fixed-shape pytree of per-wave ring buffers (sized
by ``EngineConfig.waves_cap()``) that rides in :class:`EngineState.trace`
and is written, wave by wave, by three record hooks the engine calls from
its phase functions:

=================  ========================================================
hook               fields written (at index ``state.wave``)
=================  ========================================================
:func:`record_execute`   wave_size, execs, dep_aborts, exec_reads,
                         exec_lanes, blocked_ids / blockers (level 2)
:func:`record_index`     dirty_regions, mv_entries
:func:`record_validate`  val_aborts, val_reads, skip_hits, skip_misses,
                         skip_fallback, frontier, frontier_stall
=================  ========================================================

Cost model — ``EngineConfig.trace_level`` is STATIC:

* level 0 (default): :func:`init_trace` returns ``None`` and the engine
  never calls a record hook (plain Python ``if cfg.trace_level`` at the
  call sites), so the compiled program is *exactly* today's engine — not
  "the same after DCE", the tracing code is never traced at all.
* level 1: the per-wave scalar counters — one ``(cap,)`` buffer per field,
  one dynamic-index ``.set`` per field per wave.
* level 2: level 1 plus the ``(cap, window)`` abort-attribution edges
  (which txn blocked on which ESTIMATE writer, per wave).

Multi-device (``cfg.dist``): every field derived from the replicated
scheduler state (sizes, aborts, frontier, read counts) is bit-identical on
all devices and travels replicated; ``mv_entries``, ``dirty_regions``, and
``exec_lanes`` are *per-device* quantities (each device's LOCAL index
occupancy / locally dirtied regions / executed lane slice of the
partitioned wave), and :func:`merge_device_traces` folds them into
``(n_devices, cap)`` buffers with ONE ``all_gather`` as the block exits the
``shard_map`` — the load-balance view a Zipfian region skew shows up in.

Counter invariants (property-tested in ``tests/test_obs.py``):

* ``wave_size[w] == execs[w] + dep_aborts[w]`` — every selected lane either
  finishes or dep-aborts;
* ``execs/dep_aborts/val_aborts[:waves].sum()`` equal the corresponding
  :class:`~repro.core.types.BlockStats` scalars exactly;
* ``frontier`` is monotone and reaches ``n_txns`` iff the block committed;
* every live blocker edge respects the preset order
  (``blockers < blocked_ids``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import NO_LOC

#: Sentinel for empty lanes in the level-2 edge buffers.
NO_TXN = -1


class WaveTrace(NamedTuple):
    """Per-wave telemetry ring buffers (shapes: cap = ``cfg.waves_cap()``,
    win = ``cfg.window``, D = mesh size after :func:`merge_device_traces`).

    Rows past the block's actual wave count are left at their init values
    (zeros; edge buffers at :data:`NO_TXN`); hosts trim with
    ``BlockResult.waves``.
    """

    # -- level >= 1: per-wave scalar counters -------------------------------
    frontier: jax.Array       # (cap,) i32 commit frontier at end of wave
    wave_size: jax.Array      # (cap,) i32 lanes selected (attempted execs)
    execs: jax.Array          # (cap,) i32 lanes that finished execution
    dep_aborts: jax.Array     # (cap,) i32 lanes aborted on an ESTIMATE read
    val_aborts: jax.Array     # (cap,) i32 validation failures this wave
    exec_reads: jax.Array     # (cap,) i32 live read resolutions issued by
                              #   the wave's executions
    val_reads: jax.Array      # (cap,) i32 read lanes issued by validation
                              #   (full pass: n*R; windowed: vw*R; dirty
                              #   gather path: cap_rows*R)
    skip_hits: jax.Array      # (cap,) i32 executed rows skipped as
                              #   version-clean (dirty-validation skip)
    skip_misses: jax.Array    # (cap,) i32 rows that needed validation
    skip_fallback: jax.Array  # (cap,) bool wave fell back to the full pass
    dirty_regions: jax.Array  # (cap,) i32 regions dirtied by the wave's
                              #   update; -1 under mv_update='rebuild'.
                              #   ((D, cap) per-device after dist merge)
    mv_entries: jax.Array     # (cap,) i32 live MV index entries after the
                              #   index phase ((D, cap) local per-device
                              #   after dist merge)
    exec_lanes: jax.Array     # (cap,) i32 live lanes THIS view executed
                              #   (single-device: == wave_size; (D, cap)
                              #   per-device lane-partition slice sizes
                              #   after dist merge)
    frontier_stall: jax.Array  # (cap,) i32 consecutive waves (this one
                              #   included) without frontier progress; 0
                              #   when the wave advanced it — the liveness
                              #   counter the degradation guard watches
    # -- level >= 2: abort attribution edges --------------------------------
    blocked_ids: Any = None   # (cap, win) i32 txn ids dep-aborted this wave,
                              #   NO_TXN on non-blocked lanes
    blockers: Any = None      # (cap, win) i32 the ESTIMATE writer each
                              #   blocked txn waits on, NO_TXN likewise
    # -- block-level flags (set once, post-loop) ----------------------------
    degraded: Any = None      # () bool the block committed via the
                              #   sequential degradation fallback
                              #   (repro.guard.degrade); False scalar at
                              #   level >= 1


def init_trace(cfg) -> WaveTrace | None:
    """Fresh zeroed buffers for one block (``None`` at trace level 0)."""
    if cfg.trace_level <= 0:
        return None
    cap = cfg.waves_cap()
    count = lambda: jnp.zeros((cap,), jnp.int32)
    tr = WaveTrace(
        frontier=count(), wave_size=count(), execs=count(),
        dep_aborts=count(), val_aborts=count(), exec_reads=count(),
        val_reads=count(), skip_hits=count(), skip_misses=count(),
        skip_fallback=jnp.zeros((cap,), jnp.bool_),
        dirty_regions=count(), mv_entries=count(), exec_lanes=count(),
        frontier_stall=count(), degraded=jnp.asarray(False))
    if cfg.trace_level >= 2:
        edges = jnp.full((cap, cfg.window), NO_TXN, jnp.int32)
        tr = tr._replace(blocked_ids=edges, blockers=edges)
    return tr


def _i32sum(mask: jax.Array) -> jax.Array:
    return mask.sum(dtype=jnp.int32)


def record_execute(trace: WaveTrace, wave: jax.Array, active_ids: jax.Array,
                   active_mask: jax.Array, success: jax.Array,
                   blocked: jax.Array, res, exec_lanes: jax.Array) -> WaveTrace:
    """Execute-phase counters + (level 2) the wave's dep-abort edges.

    ``res`` is the wave's :class:`~repro.core.types.ExecResult`;
    ``success``/``blocked`` partition ``active_mask`` (a lane either
    finishes or hits an ESTIMATE), which is the per-wave decomposition of
    ``BlockStats.execs``/``dep_aborts``.  ``exec_lanes`` is the backend's
    ``trace_exec_lanes`` — the live lanes THIS view executed (per-device
    under the dist backend's lane partition).
    """
    w = wave
    live_reads = (res.read_locs != NO_LOC) & active_mask[:, None]
    trace = trace._replace(
        wave_size=trace.wave_size.at[w].set(_i32sum(active_mask)),
        execs=trace.execs.at[w].set(_i32sum(success)),
        dep_aborts=trace.dep_aborts.at[w].set(_i32sum(blocked)),
        exec_reads=trace.exec_reads.at[w].set(_i32sum(live_reads)),
        exec_lanes=trace.exec_lanes.at[w].set(exec_lanes))
    if trace.blocked_ids is not None:
        trace = trace._replace(
            blocked_ids=trace.blocked_ids.at[w].set(
                jnp.where(blocked, active_ids, NO_TXN)),
            blockers=trace.blockers.at[w].set(
                jnp.where(blocked, res.blocker, NO_TXN)))
    return trace


def record_index(trace: WaveTrace, wave: jax.Array, backend, index,
                 write_locs: jax.Array, dirty) -> WaveTrace:
    """Index-phase counters: this wave's dirty-region count (``-1`` on the
    rebuild reference path, which has no delta) and the post-update live
    entry count — both PER-DEVICE quantities under the dist backend."""
    w = wave
    n_dirty = (backend.trace_dirty_count(dirty) if dirty is not None
               else jnp.asarray(-1, jnp.int32))
    return trace._replace(
        dirty_regions=trace.dirty_regions.at[w].set(n_dirty),
        mv_entries=trace.mv_entries.at[w].set(
            backend.trace_index_size(index, write_locs)))


class ValTraceAux(NamedTuple):
    """What :func:`record_validate` needs from the validation phase."""

    val_reads: jax.Array      # () i32 read lanes issued
    skip_hits: jax.Array      # () i32 rows skipped version-clean
    skip_misses: jax.Array    # () i32 rows examined
    skip_fallback: jax.Array  # () bool full-pass fallback taken


def record_validate(trace: WaveTrace, wave: jax.Array, fail: jax.Array,
                    frontier: jax.Array, aux: ValTraceAux) -> WaveTrace:
    """Validation-phase counters + the end-of-wave commit frontier.

    Also maintains ``frontier_stall``: consecutive waves (this one
    included) in which the frontier failed to advance — read back from the
    previous wave's row, so the counter stays in-jit and O(1) per wave.
    """
    w = wave
    prev_w = jnp.maximum(w - 1, 0)
    prev_frontier = jnp.where(w > 0, trace.frontier[prev_w], 0)
    prev_stall = jnp.where(w > 0, trace.frontier_stall[prev_w], 0)
    stall = jnp.where(frontier > prev_frontier, 0, prev_stall + 1)
    return trace._replace(
        val_aborts=trace.val_aborts.at[w].set(_i32sum(fail)),
        frontier=trace.frontier.at[w].set(frontier),
        val_reads=trace.val_reads.at[w].set(aux.val_reads),
        skip_hits=trace.skip_hits.at[w].set(aux.skip_hits),
        skip_misses=trace.skip_misses.at[w].set(aux.skip_misses),
        skip_fallback=trace.skip_fallback.at[w].set(aux.skip_fallback),
        frontier_stall=trace.frontier_stall.at[w].set(
            stall.astype(jnp.int32)))


def merge_device_traces(trace: WaveTrace, axis_name: str) -> WaveTrace:
    """Fold per-device buffers into the global trace (dist engine exit).

    Called INSIDE the ``shard_map`` after the engine loop: stacks the three
    genuinely per-device fields and ``all_gather``s them once along the
    mesh axis, turning their ``(cap,)`` local buffers into ``(D, cap)``
    per-device views (replicated, like every other output of the dist
    engine).  All remaining fields are functions of the replicated
    scheduler state and pass through unchanged.
    """
    local = jnp.stack([trace.dirty_regions, trace.mv_entries,
                       trace.exec_lanes])                        # (3, cap)
    gathered = jax.lax.all_gather(local, axis_name)              # (D, 3, cap)
    return trace._replace(dirty_regions=gathered[:, 0],
                          mv_entries=gathered[:, 1],
                          exec_lanes=gathered[:, 2])

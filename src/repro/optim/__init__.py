"""AdamW with bf16/int8 optimizer-state compression."""

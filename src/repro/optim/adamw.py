"""AdamW with global-norm clipping, warmup+cosine schedule and
optimizer-state compression.

Distributed notes:
* m/v inherit the parameter sharding (FSDP/TP), so ZeRO-1 partitioning of
  optimizer state is automatic under jit.
* ``state_dtype='bfloat16'`` halves optimizer-state HBM; ``'int8'`` stores
  m/v as block-quantized int8 (absmax per 128-element block, f32 scales —
  ~1.03 bytes/param/moment).  int8 is what fits the 775B llama4-maverick
  config in 16 GB/chip on a single 256-chip pod (EXPERIMENTS.md §Dry-run).
  Math is always f32; storage is quantized on write.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"       # float32 | bfloat16 | int8


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


# -- block-quantized moment storage -----------------------------------------

def _padded(n: int) -> int:
    return -(-n // QBLOCK) * QBLOCK


def _quant_init(p) -> dict:
    last = _padded(p.shape[-1]) if p.ndim else QBLOCK
    lead = p.shape[:-1] if p.ndim else ()
    return {"q": jnp.zeros(lead + (last,), jnp.int8),
            "scale": jnp.zeros(lead + (last // QBLOCK,), F32)}


def _dequant(qt: dict, shape) -> jax.Array:
    q = qt["q"].astype(F32)
    lead = q.shape[:-1]
    nb = q.shape[-1] // QBLOCK
    x = q.reshape(lead + (nb, QBLOCK)) * qt["scale"][..., None]
    x = x.reshape(lead + (nb * QBLOCK,))
    if not shape:
        return x[..., 0]
    return x[..., : shape[-1]]


def _quant(x: jax.Array) -> dict:
    if x.ndim == 0:
        x = x[None]
    pad = _padded(x.shape[-1]) - x.shape[-1]
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    lead = x.shape[:-1]
    nb = x.shape[-1] // QBLOCK
    xb = x.reshape(lead + (nb, QBLOCK))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    q = jnp.round(xb / jnp.maximum(scale, 1e-12)[..., None])
    return {"q": q.reshape(lead + (nb * QBLOCK,)).astype(jnp.int8),
            "scale": scale}


def init(params, cfg: AdamWConfig) -> OptState:
    if cfg.state_dtype == "int8":
        mk = lambda p: _quant_init(p)
    else:
        dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else F32
        mk = lambda p: jnp.zeros(p.shape, dt)
    return OptState(m=jax.tree_util.tree_map(mk, params),
                    v=jax.tree_util.tree_map(mk, params),
                    step=jnp.zeros((), jnp.int32))


def opt_state_specs(param_specs, cfg: AdamWConfig, is_spec):
    """Spec tree mirroring init()'s structure (int8 adds q/scale leaves)."""
    if cfg.state_dtype != "int8":
        return param_specs

    def one(spec):
        spec = tuple(spec)
        scale_spec = spec[:-1] + (None,) if spec else (None,)
        return {"q": spec if spec else (None,), "scale": scale_spec}

    return jax.tree_util.tree_map(one, param_specs, is_leaf=is_spec)


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(step.astype(F32) / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step.astype(F32) - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(F32))) for l in leaves))


def update(grads, state: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(step, cfg)
    bc1 = 1 - cfg.b1 ** step.astype(F32)
    bc2 = 1 - cfg.b2 ** step.astype(F32)
    quantized = cfg.state_dtype == "int8"
    state_dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else F32

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        mf = (_dequant(m, p.shape) if quantized else m.astype(F32))
        vf = (_dequant(v, p.shape) if quantized else v.astype(F32))
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        new_p = (p.astype(F32) - lr * delta).astype(p.dtype)
        if quantized:
            return new_p, _quant(mf), _quant(vf)
        return new_p, mf.astype(state_dt), vf.astype(state_dt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    is_q = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}
    flat_m = jax.tree_util.tree_leaves(state.m, is_leaf=is_q)
    flat_v = jax.tree_util.tree_leaves(state.v, is_leaf=is_q)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {"grad_norm": gnorm, "lr": lr}

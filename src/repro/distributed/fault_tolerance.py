"""Fault-tolerance utilities: preemption capture, straggler detection,
elastic-resume bookkeeping.

At 1000+ nodes the failure model is: (a) planned preemption (SIGTERM with a
grace window), (b) silent node slowdown (stragglers), (c) hard node loss
(handled by checkpoint/restart via the manager + deterministic data stream).
This module implements (a) and (b) host-side; (c) is exercised in tests by
killing and resuming a training run mid-stream.
"""
from __future__ import annotations

import collections
import signal
import time
from typing import Optional


class PreemptionHandler:
    """SIGTERM/SIGINT -> set a flag the train loop polls between steps."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._prev = {}
        for sig in signals:
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except (ValueError, OSError):  # non-main thread / platform
                pass

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def restore(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


class StragglerMonitor:
    """EMA-based step-time anomaly detector.

    On a real cluster each host reports step time; a host whose step time
    exceeds ``threshold``× the fleet EMA for ``patience`` consecutive steps is
    flagged for eviction and the job resumes on the remaining hosts via the
    elastic restore path (checkpoint + mesh reshape).  Single-process here: we
    detect our own anomalous steps and surface them in metrics.
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 patience: int = 3, warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.warmup = warmup
        self.ema: Optional[float] = None
        self._seen = 0
        self._consecutive = 0
        self.flagged: list[int] = []
        self._last: Optional[float] = None

    def start_step(self) -> None:
        self._last = time.monotonic()

    def end_step(self, step: int) -> dict:
        assert self._last is not None
        dt = time.monotonic() - self._last
        self._seen += 1
        straggling = False
        if self.ema is None:
            self.ema = dt
        else:
            if self._seen > self.warmup and dt > self.threshold * self.ema:
                self._consecutive += 1
                straggling = True
                if self._consecutive >= self.patience:
                    self.flagged.append(step)
                    self._consecutive = 0
            else:
                self._consecutive = 0
            # EMA excludes anomalous steps to stay a robust baseline.
            if not straggling:
                self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return {"step_time_s": dt, "step_time_ema_s": self.ema,
                "straggling": straggling}


def observe(record: collections.abc.Callable = print):
    """Convenience logger hook."""
    return record

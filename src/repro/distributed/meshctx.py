"""Global mesh context + sharding-constraint helpers.

Models are written against *logical* axes:

    BATCH  -> ("pod", "data") when a pod axis exists, else ("data",)
    MODEL  -> "model"

``constrain`` is a no-op when no mesh is active (CPU smoke tests), so model
code is identical between the laptop path and the 512-chip dry-run.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

BATCH = "__batch__"
MODEL = "__model__"

_ACTIVE_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _ACTIVE_MESH = prev


def is_spec(s) -> bool:
    """True for spec leaves: plain tuples of axis names / None.

    NamedTuples (e.g. OptState) are containers, not specs.
    """
    return (isinstance(s, tuple) and not hasattr(s, "_fields")
            and all(e is None or isinstance(e, (str, tuple)) for e in s))


def tree_shardings(spec_tree, mesh: Optional[Mesh] = None):
    """Map a pytree of spec tuples to NamedShardings."""
    import jax as _jax
    return _jax.tree_util.tree_map(
        lambda s: sharding(s, mesh), spec_tree, is_leaf=is_spec)


def tree_shardings_for(spec_tree, struct_tree, mesh: Optional[Mesh] = None):
    """Shardings sanitized against concrete shapes: axes whose dimension does
    not divide the mesh-axis size are dropped (e.g. global_batch=1 decode)."""
    import jax as _jax
    mesh = mesh or _ACTIVE_MESH

    def one(spec, struct):
        resolved = tuple(resolve_spec(spec, mesh))
        safe = []
        for dim, axis in zip(struct.shape,
                             resolved + (None,) * len(struct.shape)):
            size = _axis_size(mesh, axis)
            safe.append(axis if size == 1 or (size > 1 and dim % size == 0)
                        else None)
        return NamedSharding(mesh, P(*safe))

    flat_spec = _jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec)
    flat_struct = _jax.tree_util.tree_leaves(struct_tree)
    treedef = _jax.tree_util.tree_structure(struct_tree)
    return _jax.tree_util.tree_unflatten(
        treedef, [one(s, t) for s, t in zip(flat_spec, flat_struct)])


def batch_axes(mesh: Optional[Mesh] = None):
    mesh = mesh or _ACTIVE_MESH
    if mesh is None:
        return None
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def resolve_spec(spec, mesh: Optional[Mesh] = None) -> P:
    """Map logical axis names in a spec tuple to concrete mesh axes."""
    mesh = mesh or _ACTIVE_MESH
    out = []
    for s in spec:
        if s == BATCH:
            out.append(batch_axes(mesh))
        elif s == MODEL:
            out.append("model")
        else:
            out.append(s)
    return P(*out)


def sharding(spec, mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or _ACTIVE_MESH
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_spec(spec, mesh))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op without one).

    Axes whose dimension does not divide the mesh-axis size are dropped from
    the spec (e.g. batch=1 long-context decode stays replicated over data).
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    resolved = resolve_spec(spec, mesh)
    safe = []
    for dim, axis in zip(x.shape, tuple(resolved) + (None,) * x.ndim):
        size = _axis_size(mesh, axis)
        safe.append(axis if (size > 1 and dim % size == 0) or size == 1
                    else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*safe)))

"""Mesh context, sharding helpers, fault-tolerance utilities."""

"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8.

[hf:Qwen/Qwen3-30B-A3B; hf]
48L d_model=2048 32H (GQA kv=4) d_ff=768 vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                # per-expert FF width
    vocab_size=151936,
    mlp_type="swiglu",
    rope_theta=1e6,
    n_experts=128,
    top_k=8,
    moe_group_size=256,
    fsdp=True,
    remat="block",
    train_microbatches=2,
)

"""pixtral-12b [vlm] — Pixtral-ViT frontend (stub) + Mistral-Nemo backbone.

[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,            # Mistral-Nemo style fixed head_dim
    d_ff=14336,
    vocab_size=131072,
    mlp_type="swiglu",
    rope_theta=1e6,
    frontend="patch",        # STUB: input_specs provides patch embeddings
    fsdp=True,
    remat="block",
    train_microbatches=8,
)

"""Registry of assigned architectures + reduced-config factory for smoke tests."""
from __future__ import annotations

import dataclasses

from repro.configs import (falcon_mamba_7b, gemma_2b, llama4_maverick_400b,
                           nemotron4_340b, pixtral_12b, qwen1p5_110b,
                           qwen3_moe_30b, whisper_medium, yi_34b, zamba2_1p2b)
from repro.configs.base import ArchConfig

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        pixtral_12b.CONFIG,
        llama4_maverick_400b.CONFIG,
        qwen3_moe_30b.CONFIG,
        whisper_medium.CONFIG,
        zamba2_1p2b.CONFIG,
        qwen1p5_110b.CONFIG,
        yi_34b.CONFIG,
        nemotron4_340b.CONFIG,
        gemma_2b.CONFIG,
        falcon_mamba_7b.CONFIG,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 64,
                   vocab: int = 512) -> ArchConfig:
    """Same-family tiny config for CPU smoke tests (shapes asserted, no NaNs)."""
    heads = max(2, min(cfg.n_heads, 4))
    kv = 1 if cfg.n_kv_heads == 1 else max(1, min(cfg.n_kv_heads, 2))
    updates = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=heads if cfg.n_heads else 0,
        n_kv_heads=kv if cfg.n_kv_heads else 0,
        head_dim=(d_model // heads) if cfg.n_heads else None,
        d_ff=d_model * 2 if cfg.d_ff else 0,
        vocab_size=vocab,
        fsdp=False,
        remat="none",
    )
    if cfg.n_experts:
        updates.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_group_size=32)
    if cfg.ssm_state:
        updates.update(ssm_state=8)
    if cfg.encoder_layers:
        updates.update(encoder_layers=n_layers)
    if cfg.attn_every:
        updates.update(attn_every=2)
    return dataclasses.replace(cfg, **updates)

"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP.

[arXiv:2402.16819; unverified]
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp_type="sq_relu",
    fsdp=True,
    grad_accum_dtype="bfloat16",   # f32 accumulator would not fit 16 GB HBM
    remat="block",
    train_microbatches=4,
    opt_state_dtype="bfloat16",   # 340B: fp32 m+v would not fit 16 GB HBM
)

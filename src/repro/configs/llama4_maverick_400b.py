"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,              # 40 % 16 != 0 -> sequence-parallel attention
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mlp_type="swiglu",
    rope_theta=5e5,
    n_experts=128,
    top_k=1,
    moe_group_size=512,      # top-1: larger groups keep capacity >= 4
    fsdp=True,
    grad_accum_dtype="bfloat16",   # f32 accumulator would not fit 16 GB HBM
    remat="block",
    train_microbatches=8,
    opt_state_dtype="int8",       # 775B total params: int8 m/v fits 16 GB/chip
)

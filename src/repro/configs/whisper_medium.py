"""whisper-medium [audio] — encoder-decoder, conv frontend (stub).

[arXiv:2212.04356; unverified]
24L (enc) + 24L (dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,             # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,           # full MHA
    d_ff=4096,
    vocab_size=51865,        # padded to 51968 for TP sharding
    mlp_type="gelu",
    qkv_bias=True,
    use_rope=False,          # absolute sinusoidal positions
    norm_type="layernorm",
    tie_embeddings=True,
    frontend="audio",        # STUB: input_specs provides frame embeddings
    remat="block",
    train_microbatches=2,
)

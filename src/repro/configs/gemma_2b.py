"""gemma-2b [dense] — GeGLU, head_dim=256, MQA.

[arXiv:2403.08295; hf]
18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,               # 8 % 16 != 0 -> sequence-parallel attention
    n_kv_heads=1,            # MQA
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_type="geglu",
    tie_embeddings=True,
    remat="block",
)

"""zamba2-1.2b [hybrid] — Mamba-2 backbone + one shared attention block.

[arXiv:2411.15242; hf]
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,             # mamba2 layers
    d_model=2048,
    n_heads=32,              # shared attention block (MHA, head_dim 64)
    n_kv_heads=32,
    d_ff=8192,               # shared block MLP
    vocab_size=32000,
    mlp_type="gelu",
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,            # d_inner=4096, 64 SSD heads (head_dim=64)
    attn_every=6,            # shared block applied every 6 mamba layers
    tie_embeddings=True,
    remat="block",
    train_microbatches=8,
    supports_long=True,      # sub-quadratic: SSM + periodic bounded attention
)

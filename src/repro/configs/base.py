"""Architecture configuration schema shared by all assigned configs.

Every assigned architecture is a single :class:`ArchConfig`; the model zoo in
``repro.models`` interprets it.  Published dimensions are entered verbatim;
the only systematic deviation is vocab padding to a multiple of 256 for TP
sharding (standard practice; padded logits are masked).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: Optional[int] = None     # default: d_model // n_heads
    mlp_type: str = "swiglu"           # swiglu | geglu | sq_relu | gelu
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 1e4
    use_rope: bool = True              # False: sinusoidal absolute positions
    norm_type: str = "rmsnorm"         # rmsnorm | layernorm
    tie_embeddings: bool = False
    # -- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_group_size: int = 256          # dispatch group (capacity granularity)
    capacity_factor: float = 1.25
    # -- SSM ----------------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0                 # mamba2 (SSD) heads; 0 -> mamba1
    # -- hybrid (zamba2): one shared attention block every `attn_every` ------
    attn_every: int = 0
    # -- enc-dec (whisper) ---------------------------------------------------
    encoder_layers: int = 0
    # -- modality frontend stub ----------------------------------------------
    frontend: str = "none"             # none | patch | audio
    # -- distribution hints ---------------------------------------------------
    fsdp: bool = False                 # ZeRO-3 shard params over the data axis
    remat: str = "none"                # none | block (remat each layer block)
    opt_state_dtype: str = "float32"   # float32 | bfloat16 | int8 (compression)
    train_microbatches: int = 1        # grad-accumulation slices per step
    grad_accum_dtype: str = "float32"  # float32 | bfloat16 (accumulator width)
    # shapes this arch supports; long_* requires sub-quadratic mixing
    supports_long: bool = False

    # -------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        attn = self.n_heads * hd * d + 2 * self.n_kv_heads * hd * d \
            + self.n_heads * hd * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.mlp_type in ("swiglu", "geglu"):
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        per_layer = 0
        n_attn_layers = self.n_layers
        if self.family == "ssm":
            n_attn_layers = 0
        ssm = 0
        if self.ssm_state:
            di = self.d_inner
            ssm = (2 * d * di            # in_proj (x, z)
                   + di * self.ssm_conv  # conv
                   + di * self.ssm_state * 2   # A (d_inner x N) + dt bias etc approx
                   + di * (self.ssm_state * 2 + 2)  # B,C,dt projections approx
                   + di * d)             # out_proj
        if self.family == "ssm":
            per_layer = ssm
        elif self.family == "hybrid":
            per_layer = ssm  # + one shared attn block accounted below
        elif self.n_experts:
            per_layer = attn + self.n_experts * mlp + d * self.n_experts
        else:
            per_layer = attn + mlp
        total = self.n_layers * per_layer
        if self.family == "hybrid":
            total += attn + mlp          # the single shared block
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp)
            total += self.n_layers * attn  # decoder cross-attention
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp = 3 * d * f if self.mlp_type in ("swiglu", "geglu") else 2 * d * f
        inactive = self.n_layers * (self.n_experts - self.top_k) * mlp
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatch: Optional[int] = None   # per-step accumulation slice (train)


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}

"""falcon-mamba-7b [ssm] — pure Mamba-1, attention-free.

[arXiv:2410.05355; unverified]
64L d_model=4096 (attn-free) vocab=65024, ssm_state=16.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,            # d_inner = 8192
    fsdp=True,
    remat="block",
    train_microbatches=8,
    supports_long=True,
)

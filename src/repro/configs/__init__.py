"""Assigned architecture configs (exact published dims) + registry."""
from repro.configs.base import (SHAPES, SHAPES_BY_NAME, ArchConfig,
                                ShapeConfig)
from repro.configs.registry import ARCHS, get_arch, reduced_config

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "SHAPES_BY_NAME", "ARCHS",
           "get_arch", "reduced_config"]

"""yi-34b [dense] — llama-arch GQA.

[arXiv:2403.04652; hf]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,              # 56 % 16 != 0 -> sequence-parallel attention
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    mlp_type="swiglu",
    rope_theta=5e6,
    fsdp=True,
    remat="block",
    train_microbatches=8,
)

"""Multi-version read resolution (the paper's MVMemory.read, Algorithm 2 L47-54).

A read of ``loc`` by ``tx_j`` must resolve to the write of the *highest* writer
``tx_i`` with ``i < j`` that has a live entry at ``loc`` — plus the writer's
incarnation and ESTIMATE flag.

Two TPU-friendly backends replace the paper's concurrent hashmap:

* ``sorted``  — encode every live write slot as the key ``loc*(n+1)+writer`` and
  keep the key array sorted.  A read is then ``searchsorted(keys, loc*(n+1)+j)-1``
  followed by one bounds check.  O((nW + queries)·log nW) per wave, independent of
  the location-universe size.  This is the production path.

* ``dense``   — materialize a (n+1, L) exclusive running-argmax table
  ``last_writer[j, l] = max{i < j : tx_i writes l}``.  Reads are O(1) gathers.
  Only viable when n*L is small; this is the layout the ``mv_resolve`` Pallas
  kernel produces (see src/repro/kernels/mv_resolve).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import NO_LOC, STORAGE

_KEY_MAX = jnp.iinfo(jnp.int32).max


class MVIndex(NamedTuple):
    """Sorted multi-version index over all live write slots."""

    keys: jax.Array      # (n*W,) i32 ascending; dead slots pushed to +inf
                         # (key = loc*(n+1)+writer; EngineConfig asserts no overflow)
    txn: jax.Array       # (n*W,) i32 writer txn index per sorted entry
    slot: jax.Array      # (n*W,) i32 writer's write slot per sorted entry
    n_txns: int          # static


def build_index(write_locs: jax.Array, n_txns: int) -> MVIndex:
    """Sort all live (loc, writer) write slots into a binary-searchable index."""
    n, w = write_locs.shape
    if write_locs.dtype != jnp.int32:
        raise TypeError(f"write_locs must be int32, got {write_locs.dtype}")
    writer = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, w))
    slot = jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32)[None, :], (n, w))
    live = write_locs != NO_LOC
    keys = write_locs * (n_txns + 1) + writer
    assert keys.dtype == jnp.int32, keys.dtype  # EngineState.idx_keys contract
    keys = jnp.where(live, keys, _KEY_MAX).reshape(-1)
    # NOTE (§Perf engine iteration 4, refuted): replacing argsort+gathers
    # with a 3-operand lax.sort co-sort measured ~30% SLOWER on the XLA CPU
    # backend; argsort+gather kept.
    order = jnp.argsort(keys)
    return MVIndex(
        keys=keys[order],
        txn=writer.reshape(-1)[order],
        slot=slot.reshape(-1)[order],
        n_txns=n_txns,
    )


class ReadResolution(NamedTuple):
    found: jax.Array       # () bool — a lower writer exists (paper: status OK)
    writer: jax.Array      # () i32 — writer txn idx, or STORAGE
    slot: jax.Array        # () i32 — writer's write slot (for value gather)
    inc: jax.Array         # () i32 — writer's incarnation stamp (version)
    is_estimate: jax.Array  # () bool — entry is an ESTIMATE (paper: READ_ERROR)


def resolve(index: MVIndex, estimate: jax.Array, incarnation: jax.Array,
            loc: jax.Array, reader: jax.Array) -> ReadResolution:
    """Resolve one read (vmappable). ``reader`` may be BLOCK.size() for snapshot."""
    # Highest key strictly below loc*(n+1)+reader with the same loc.
    query = loc * (index.n_txns + 1) + reader
    pos = jnp.searchsorted(index.keys, query, side="left") - 1
    safe = jnp.maximum(pos, 0)
    key = index.keys[safe]
    found = (pos >= 0) & (key // (index.n_txns + 1) == loc) & (loc != NO_LOC)
    writer = jnp.where(found, index.txn[safe], STORAGE)
    slot = jnp.where(found, index.slot[safe], 0)
    safe_writer = jnp.where(found, writer, 0)
    is_est = found & estimate[safe_writer]
    inc = jnp.where(found, incarnation[safe_writer], -1)
    return ReadResolution(found=found, writer=writer.astype(jnp.int32),
                          slot=slot.astype(jnp.int32), inc=inc.astype(jnp.int32),
                          is_estimate=is_est)


def resolve_value(write_vals: jax.Array, storage: jax.Array, res: ReadResolution,
                  loc: jax.Array) -> jax.Array:
    """Value of a resolution: writer's slot value, else storage[loc]."""
    safe_loc = jnp.clip(loc, 0, storage.shape[0] - 1)
    from_mv = write_vals[jnp.where(res.found, res.writer, 0),
                         jnp.where(res.found, res.slot, 0)]
    return jnp.where(res.found, from_mv, storage[safe_loc])


# ---------------------------------------------------------------------------
# Dense backend: (n+1, L) exclusive running argmax of writers per location.
# ---------------------------------------------------------------------------

def dense_last_writer(write_locs: jax.Array, n_locs: int, *,
                      use_pallas: bool = False) -> jax.Array:
    """Build ``last_writer[j, l] = max{i < j : tx_i has a live write at l}`` (else -1).

    The scatter builds the per-(txn, loc) write marks; the exclusive cumulative
    max along the txn axis is the hot loop and is what the ``mv_resolve`` Pallas
    kernel implements for TPU.
    """
    n, w = write_locs.shape
    marks = jnp.full((n, n_locs), -1, dtype=jnp.int32)
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, w))
    live = write_locs != NO_LOC
    cols = jnp.where(live, write_locs, 0)
    vals = jnp.where(live, rows, -1)
    marks = marks.at[rows, cols].max(vals)
    if use_pallas:
        from repro.kernels.mv_resolve import ops as mv_ops
        return mv_ops.exclusive_cummax(marks)
    zero = jnp.full((1, n_locs), -1, dtype=jnp.int32)
    inclusive = jax.lax.cummax(marks, axis=0)
    return jnp.concatenate([zero, inclusive], axis=0)


def dense_resolve(last_writer: jax.Array, write_locs: jax.Array,
                  estimate: jax.Array, incarnation: jax.Array, loc: jax.Array,
                  reader: jax.Array) -> ReadResolution:
    """Resolve one read against the dense table (vmappable)."""
    safe_loc = jnp.clip(loc, 0, last_writer.shape[1] - 1)
    writer = last_writer[reader, safe_loc]
    found = (writer >= 0) & (loc != NO_LOC)
    safe_writer = jnp.where(found, writer, 0)
    # Recover which slot of the writer holds this location.
    slot_match = write_locs[safe_writer] == loc
    slot = jnp.argmax(slot_match, axis=-1).astype(jnp.int32)
    is_est = found & estimate[safe_writer]
    inc = jnp.where(found, incarnation[safe_writer], -1)
    return ReadResolution(found=found, writer=jnp.where(found, writer, STORAGE),
                          slot=slot, inc=inc.astype(jnp.int32), is_estimate=is_est)

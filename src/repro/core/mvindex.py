"""DEPRECATED shim — multi-version read resolution moved to :mod:`repro.core.mv`.

This module kept the two original hard-wired code paths (``sorted`` and
``dense``) as free functions.  They now live behind the
:class:`~repro.core.mv.base.MVBackend` protocol (``repro.core.mv``), which
adds the ``sharded`` backend for beyond-int32 location universes.  The
original API is preserved here verbatim for downstream callers; new code
should use ``mv.make_backend(cfg)`` / the backend classes directly.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple

import jax

from repro.core.mv.base import ReadResolution, resolve_value  # noqa: F401
from repro.core.mv.dense import dense_last_writer, dense_resolve  # noqa: F401
from repro.core.mv.sorted_index import (_KEY_MAX, resolve_sorted,  # noqa: F401
                                        sort_write_slots)

warnings.warn(
    "repro.core.mvindex is deprecated; use repro.core.mv (MVBackend protocol)",
    DeprecationWarning, stacklevel=2)


class MVIndex(NamedTuple):
    """Sorted multi-version index over all live write slots (legacy layout)."""

    keys: jax.Array      # (n*W,) i32 ascending; dead slots pushed to +inf
                         # (key = loc*(n+1)+writer; EngineConfig rejects
                         # overflow for non-sharded backends)
    txn: jax.Array       # (n*W,) i32 writer txn index per sorted entry
    slot: jax.Array      # (n*W,) i32 writer's write slot per sorted entry
    n_txns: int          # static


def build_index(write_locs: jax.Array, n_txns: int) -> MVIndex:
    """Sort all live (loc, writer) write slots into a binary-searchable index."""
    idx = sort_write_slots(write_locs, n_txns)
    return MVIndex(keys=idx.keys, txn=idx.txn, slot=idx.slot, n_txns=n_txns)


def resolve(index: MVIndex, estimate: jax.Array, incarnation: jax.Array,
            loc: jax.Array, reader: jax.Array) -> ReadResolution:
    """Resolve one read (vmappable). ``reader`` may be BLOCK.size() for snapshot."""
    from repro.core.mv.sorted_index import SortedIndex
    return resolve_sorted(SortedIndex(index.keys, index.txn, index.slot),
                          index.n_txns, estimate, incarnation, loc, reader)

"""Sorted MV backend: one flat binary-searchable key array.

Every live write slot is encoded as the key ``loc*(n_txns+1)+writer`` and the
key array is kept sorted.  A read is ``searchsorted(keys, loc*(n+1)+reader) -
1`` followed by one bounds check: O((nW + queries)·log nW) per wave,
independent of the location-universe size.  This is the production path for
single-region universes; its int32 keys cap the universe at
``(2^31 - 1 - n) // (n+1)`` locations — beyond that, use the ``sharded``
backend (shard-local keys).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.mv.base import (BackendDefaults, ReadResolution,
                                finalize_resolution, update_by_rebuild)
from repro.core.types import NO_LOC

_KEY_MAX = jnp.iinfo(jnp.int32).max


class SortedIndex(NamedTuple):
    """Sorted multi-version index over all live write slots (arrays only)."""

    keys: jax.Array      # (n*W,) i32 ascending loc*(n+1)+writer; dead = +inf
    txn: jax.Array       # (n*W,) i32 writer txn index per sorted entry
    slot: jax.Array      # (n*W,) i32 writer's write slot per sorted entry
    version: Any = None  # (1,) i32 region version (single flat region)


def sort_write_slots(write_locs: jax.Array, n_txns: int) -> SortedIndex:
    """Sort all live (loc, writer) write slots into a binary-searchable index."""
    n, w = write_locs.shape
    if write_locs.dtype != jnp.int32:
        raise TypeError(f"write_locs must be int32, got {write_locs.dtype}")
    writer = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, w))
    slot = jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32)[None, :], (n, w))
    live = write_locs != NO_LOC
    keys = write_locs * (n_txns + 1) + writer
    assert keys.dtype == jnp.int32, keys.dtype  # EngineConfig rejects overflow
    keys = jnp.where(live, keys, _KEY_MAX).reshape(-1)
    # NOTE (§Perf engine iteration 4, refuted): replacing argsort+gathers
    # with a 3-operand lax.sort co-sort measured ~30% SLOWER on the XLA CPU
    # backend; argsort+gather kept.
    order = jnp.argsort(keys)
    return SortedIndex(keys=keys[order], txn=writer.reshape(-1)[order],
                       slot=slot.reshape(-1)[order])


def resolve_sorted(index: SortedIndex, n_txns: int, estimate: jax.Array,
                   incarnation: jax.Array, loc: jax.Array,
                   reader: jax.Array) -> ReadResolution:
    """Resolve one read (vmappable). ``reader`` may be BLOCK.size() for snapshot."""
    # Highest key strictly below loc*(n+1)+reader with the same loc.
    query = loc * (n_txns + 1) + reader
    pos = jnp.searchsorted(index.keys, query, side="left") - 1
    safe = jnp.maximum(pos, 0)
    key = index.keys[safe]
    found = (pos >= 0) & (key // (n_txns + 1) == loc) & (loc != NO_LOC)
    return finalize_resolution(found, index.txn[safe], index.slot[safe],
                               estimate, incarnation)


@dataclasses.dataclass(frozen=True)
class SortedBackend(BackendDefaults):
    """MVBackend over one flat sorted key array (see module docstring)."""

    n_txns: int
    name: str = dataclasses.field(default="sorted", init=False)

    @property
    def n_regions(self) -> int:
        return 1            # one flat region: any write-set change is dirty

    def region_of(self, locs: jax.Array) -> jax.Array:
        return jnp.zeros_like(locs)

    def build(self, write_locs: jax.Array) -> SortedIndex:
        idx = sort_write_slots(write_locs, self.n_txns)
        return idx._replace(version=jnp.zeros((1,), jnp.int32))

    def update(self, index: SortedIndex, write_locs: jax.Array,
               txn_ids: jax.Array, old_write_locs: jax.Array,
               new_write_locs: jax.Array) -> tuple[SortedIndex, jax.Array]:
        return update_by_rebuild(self, index, write_locs, old_write_locs,
                                 new_write_locs)

    def make_resolver(self, index: SortedIndex, write_locs: jax.Array,
                      estimate: jax.Array, incarnation: jax.Array):
        def resolver(loc, reader):
            return resolve_sorted(index, self.n_txns, estimate, incarnation,
                                  loc, reader)
        return resolver

    def guard_index_ok(self, index: SortedIndex,
                       write_locs: jax.Array) -> jax.Array:
        """Keys ascending (binary-search precondition) and live entry
        count conserved — one index entry per live write slot.  Live keys
        are strictly below the dead +inf sentinel (EngineConfig's int32
        bound leaves headroom), so counting non-sentinels counts entries."""
        live = (write_locs != NO_LOC).sum(dtype=jnp.int32)
        entries = (index.keys != _KEY_MAX).sum(dtype=jnp.int32)
        ascending = (jnp.diff(index.keys) >= 0).all()
        return ascending & (entries == live)

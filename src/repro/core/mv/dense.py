"""Dense MV backend: (n+1, L) exclusive running-argmax last-writer table.

``last_writer[j, l] = max{i < j : tx_i writes l}`` materialized for every
(reader, location) pair; reads are O(1) gathers.  Only viable when ``n*L`` is
small — this is the layout the ``mv_resolve`` Pallas kernel produces (see
``src/repro/kernels/mv_resolve``), so it doubles as the kernel's host-side
reference backend.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.mv.base import (BackendDefaults, ReadResolution,
                                update_by_rebuild)
from repro.core.types import NO_LOC, STORAGE


class DenseIndex(NamedTuple):
    last_writer: jax.Array   # (n+1, L) i32 exclusive running argmax, -1 = none
    version: Any = None      # (1,) i32 region version (single flat region)


def dense_last_writer(write_locs: jax.Array, n_locs: int, *,
                      use_pallas: bool = False) -> jax.Array:
    """Build ``last_writer[j, l] = max{i < j : tx_i has a live write at l}`` (else -1).

    The scatter builds the per-(txn, loc) write marks; the exclusive cumulative
    max along the txn axis is the hot loop and is what the ``mv_resolve`` Pallas
    kernel implements for TPU.
    """
    n, w = write_locs.shape
    marks = jnp.full((n, n_locs), -1, dtype=jnp.int32)
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, w))
    live = write_locs != NO_LOC
    cols = jnp.where(live, write_locs, 0)
    vals = jnp.where(live, rows, -1)
    marks = marks.at[rows, cols].max(vals)
    if use_pallas:
        from repro.kernels.mv_resolve import ops as mv_ops
        return mv_ops.exclusive_cummax(marks)
    zero = jnp.full((1, n_locs), -1, dtype=jnp.int32)
    inclusive = jax.lax.cummax(marks, axis=0)
    return jnp.concatenate([zero, inclusive], axis=0)


def dense_resolve(last_writer: jax.Array, write_locs: jax.Array,
                  estimate: jax.Array, incarnation: jax.Array, loc: jax.Array,
                  reader: jax.Array) -> ReadResolution:
    """Resolve one read against the dense table (vmappable)."""
    safe_loc = jnp.clip(loc, 0, last_writer.shape[1] - 1)
    writer = last_writer[reader, safe_loc]
    found = (writer >= 0) & (loc != NO_LOC)
    safe_writer = jnp.where(found, writer, 0)
    # Recover which slot of the writer holds this location.
    slot_match = write_locs[safe_writer] == loc
    slot = jnp.argmax(slot_match, axis=-1).astype(jnp.int32)
    is_est = found & estimate[safe_writer]
    inc = jnp.where(found, incarnation[safe_writer], -1)
    return ReadResolution(found=found, writer=jnp.where(found, writer, STORAGE),
                          slot=slot, inc=inc.astype(jnp.int32), is_estimate=is_est)


@dataclasses.dataclass(frozen=True)
class DenseBackend(BackendDefaults):
    """MVBackend over the materialized last-writer table (see module docstring)."""

    n_txns: int
    n_locs: int
    use_pallas: bool = False
    name: str = dataclasses.field(default="dense", init=False)

    @property
    def n_regions(self) -> int:
        return 1            # one flat region: any write-set change is dirty

    def region_of(self, locs: jax.Array) -> jax.Array:
        return jnp.zeros_like(locs)

    def build(self, write_locs: jax.Array) -> DenseIndex:
        return DenseIndex(dense_last_writer(write_locs, self.n_locs,
                                            use_pallas=self.use_pallas),
                          version=jnp.zeros((1,), jnp.int32))

    def update(self, index: DenseIndex, write_locs: jax.Array,
               txn_ids: jax.Array, old_write_locs: jax.Array,
               new_write_locs: jax.Array) -> tuple[DenseIndex, jax.Array]:
        return update_by_rebuild(self, index, write_locs, old_write_locs,
                                 new_write_locs)

    def make_resolver(self, index: DenseIndex, write_locs: jax.Array,
                      estimate: jax.Array, incarnation: jax.Array):
        def resolver(loc, reader):
            return dense_resolve(index.last_writer, write_locs, estimate,
                                 incarnation, loc, reader)
        return resolver

"""Multi-version memory backends (the paper's MVMemory, Algorithm 2).

Read resolution is a first-class subsystem: every engine layer (wave engine,
baselines, snapshots, the bytecode interpreter's READ op) consumes the
:class:`~repro.core.mv.base.MVBackend` protocol, never a concrete index
layout.  Three registered backends:

* ``sorted``  — one flat sorted key array, ``key = loc*(n+1)+writer``.  The
  single-region production path; universe capped by int32 keys.
* ``dense``   — materialized (n+1, L) last-writer table, O(1) reads; tiny
  universes only (the ``mv_resolve`` Pallas kernel's layout).
* ``sharded`` — per-region sorted indexes with shard-local keys; survives
  arbitrarily large universes (10M+ locations) and is the seam for
  multi-device ``shard_map`` execution.

``make_backend(cfg)`` maps an :class:`~repro.core.types.EngineConfig` to its
backend instance.  See README.md in this package for the protocol contract,
the shard-local key encoding and its overflow math, and how to add a backend.
"""
from __future__ import annotations

from repro.core.mv.base import (BackendDefaults, MVBackend, ReadResolution,
                                Resolver, dirty_from_delta, resolve_value,
                                update_by_rebuild)
from repro.core.mv.dense import DenseBackend, DenseIndex
from repro.core.mv.sharded import ShardedBackend, ShardedIndex, shard_plan
from repro.core.mv.sorted_index import SortedBackend, SortedIndex

#: Backend names accepted by ``EngineConfig.backend``.
BACKENDS = ("sorted", "dense", "sharded")


def make_backend(cfg) -> MVBackend:
    """Backend instance for an :class:`~repro.core.types.EngineConfig`.

    Static per-config (pure Python, trace-time only): call freely inside
    jitted code.
    """
    if cfg.backend == "sorted":
        return SortedBackend(n_txns=cfg.n_txns)
    if cfg.backend == "dense":
        return DenseBackend(n_txns=cfg.n_txns, n_locs=cfg.n_locs,
                            use_pallas=cfg.use_pallas)
    if cfg.backend == "sharded":
        if getattr(cfg, "dist", False):
            # Region segments placed across the config's device mesh; only
            # reachable inside the dist engine's shard_map (lazy import —
            # core.dist builds on this package).
            from repro.core.dist.backend import DistShardedBackend
            return DistShardedBackend.from_config(cfg)
        return ShardedBackend.from_universe(
            cfg.n_txns, cfg.n_locs, cfg.n_shards,
            resolver_impl=cfg.resolver_impl)
    raise ValueError(f"unknown MV backend {cfg.backend!r}; "
                     f"expected one of {BACKENDS}")


__all__ = ["BackendDefaults", "MVBackend", "ReadResolution", "Resolver",
           "resolve_value", "dirty_from_delta", "update_by_rebuild",
           "SortedBackend", "SortedIndex", "DenseBackend", "DenseIndex",
           "ShardedBackend", "ShardedIndex", "shard_plan", "BACKENDS",
           "make_backend"]

"""MVBackend protocol: multi-version read resolution as a first-class subsystem.

The paper's MVMemory (Algorithm 2) answers one question: *a read of ``loc`` by
``tx_j`` resolves to the write of the highest writer ``tx_i`` with ``i < j``
that has a live entry at ``loc``* — plus the writer's incarnation stamp and
ESTIMATE flag.  Everything else in the engine (dependency registration,
validation, the commit frontier, snapshots) consumes only the answer, never
the data structure that produced it.

This module pins down that seam.  A backend is an object with three methods:

* ``build(write_locs) -> index``     — turn the block's ``(n, W)`` live write
  slots into whatever pytree of arrays the backend searches.  Called once at
  engine init (and per wave on the ``mv_update='rebuild'`` reference path);
  the pytree rides in the ``lax.while_loop`` carry, so its structure and
  shapes must be fixed for a given :class:`~repro.core.types.EngineConfig`.
* ``update(index, write_locs, txn_ids, old_write_locs, new_write_locs) ->
  (index, dirty_regions)`` — apply one wave's write-set delta *incrementally*:
  drop the stale entries of the transactions in ``txn_ids`` (their previous
  live write sets arrive as ``old_write_locs``) and insert their new write
  sets (``new_write_locs``).  ``write_locs`` is the full post-wave ``(n, W)``
  matrix, so ``build(write_locs)``-based shims are always a correct fallback;
  the result must be **byte-identical** (keys/txn/slot) to that fresh build.
  ``dirty_regions`` is an ``(n_regions,)`` bool mask of regions whose
  resolution may have changed this wave; the returned index's ``version``
  field is the old version + dirty (see below).
* ``make_resolver(index, write_locs, estimate, incarnation) -> resolver`` —
  close over the current MV state and return ``resolver(loc, reader) ->
  ReadResolution``, a scalar function the engine vmaps over reads, read-set
  validation rows, and the final snapshot.

Backends additionally expose *batched/placement* hooks with protocol-
level defaults (:class:`BackendDefaults`), which is what lets the
multi-device backend (:mod:`repro.core.dist`) change data placement without
the engine caring:

* ``resolve_batch(index, write_locs, estimate, incarnation, locs, readers)``
  — resolve a flat batch of reads at once.  Default: vmap of the scalar
  resolver (which is also how the ``resolver_impl='pallas'`` kernel batches);
  the dist backend instead routes each query to the device owning its region
  (two-hop ``all_to_all``) and gathers the answers.
* ``execute_routed(index, write_locs, estimate, incarnation, active_ids,
  exec_fn)`` — run the wave's execute phase under this backend's placement.
  ``exec_fn(resolver, ids)`` is the engine's VM closure (vmapped speculative
  execution of the ``ids`` lanes reading through ``resolver``).  Default:
  identity — every lane executes here against ``make_resolver``.  The dist
  backend partitions the lanes across the mesh, executes each device's
  slice against a *routed* per-read resolver (mid-transaction reads travel
  the same two-hop ``all_to_all`` as ``resolve_batch``), and ``all_gather``s
  the :class:`~repro.core.types.ExecResult` lanes back replicated.
* ``snapshot(index, write_locs, estimate, incarnation, write_vals, storage,
  n_locs)`` — MVMemory.snapshot (paper L55-61) as one batched read of every
  location by reader ``n_txns``.  Default: ``resolve_batch`` + value gather;
  the dist backend resolves each device's own location span locally and
  ``all_gather``s the value slices.
* ``version_view(index) -> (n_regions,)`` — the global region-version vector.
  Default: ``index.version``; the dist backend ``all_gather``s the per-device
  counters (each region's counter lives with its region).
* ``bump_versions(index, dirty) -> index`` — apply an engine-side version
  bump for a global ``(n_regions,)`` dirty mask (validation-abort estimate
  flips change no index entry, so the engine bumps those regions itself).
  Default: add to ``index.version``; the dist backend adds each device's own
  slice of the mask.

Regions and versions
--------------------
Every backend partitions the location universe into ``n_regions`` contiguous
regions (flat backends have exactly one; ``sharded`` has one per shard) and
exposes ``region_of(locs)``, the vectorized location→region map.  Every index
pytree carries a ``version`` field — an ``(n_regions,)`` int32 counter that
``update`` increments for each dirty region.  The contract the engine's
dirty-region validation skip relies on (see ``engine._validate_dirty``):

    a read's resolution — found/writer/slot *and* the writer's incarnation
    and ESTIMATE stamps — can only change between two points in time if the
    version of the read location's region differs between them.

``update`` guarantees this for index-content changes because a changed txn's
stale entries live exactly at its ``old_write_locs`` (the caller must pass
the txns' true pre-update live write sets) and its fresh entries at
``new_write_locs`` — both are folded into ``dirty_regions``.  Estimate flips
from *validation* aborts change no index entry, so the engine bumps those
versions itself (the aborted txns' write regions) via ``region_of``.

Backends registered in :mod:`repro.core.mv` (``sorted`` / ``dense`` /
``sharded``) are interchangeable: the backend-equivalence property suites
(``tests/test_mv_backends.py``, ``tests/test_mv_incremental.py``) check
byte-identical snapshots AND identical abort/wave statistics, i.e.
resolution-for-resolution agreement, on both the build and update paths.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.types import NO_LOC, STORAGE


class ReadResolution(NamedTuple):
    """Answer to one MV read (all fields are scalars; vmappable)."""

    found: jax.Array       # () bool — a lower writer exists (paper: status OK)
    writer: jax.Array      # () i32 — writer txn idx, or STORAGE
    slot: jax.Array        # () i32 — writer's write slot (for value gather)
    inc: jax.Array         # () i32 — writer's incarnation stamp (version)
    is_estimate: jax.Array  # () bool — entry is an ESTIMATE (paper: READ_ERROR)


#: ``resolver(loc, reader) -> ReadResolution`` — what ``make_resolver`` returns.
Resolver = Callable[[jax.Array, jax.Array], ReadResolution]


@runtime_checkable
class MVBackend(Protocol):
    """One multi-version index implementation (see module docstring)."""

    name: str

    @property
    def n_regions(self) -> int:
        """Static region count (1 for flat backends, n_shards for sharded)."""
        ...

    def region_of(self, locs: jax.Array) -> jax.Array:
        """Vectorized location -> region id map (callers mask NO_LOC)."""
        ...

    def build(self, write_locs: jax.Array) -> Any:
        """(n, W) int32 live write locations -> index pytree (arrays only)."""
        ...

    def update(self, index: Any, write_locs: jax.Array, txn_ids: jax.Array,
               old_write_locs: jax.Array,
               new_write_locs: jax.Array) -> tuple[Any, jax.Array]:
        """Incremental per-wave delta (see module docstring).

        ``txn_ids`` is ``(window,)`` int32 with ``n_txns`` marking no-op fill
        lanes; ``old_write_locs``/``new_write_locs`` are ``(window, W)`` with
        all-NO_LOC rows for no-op lanes.  Returns ``(index, dirty_regions)``
        with keys/txn/slot byte-identical to ``build(write_locs)``.
        """
        ...

    def make_resolver(self, index: Any, write_locs: jax.Array,
                      estimate: jax.Array, incarnation: jax.Array) -> Resolver:
        """Close over the current MV state; return the per-read resolver."""
        ...

    def resolve_batch(self, index: Any, write_locs: jax.Array,
                      estimate: jax.Array, incarnation: jax.Array,
                      locs: jax.Array, readers: jax.Array) -> ReadResolution:
        """Resolve a flat ``(Q,)`` batch of reads (see module docstring)."""
        ...

    def execute_routed(self, index: Any, write_locs: jax.Array,
                       estimate: jax.Array, incarnation: jax.Array,
                       active_ids: jax.Array, exec_fn: Callable) -> Any:
        """Run ``exec_fn(resolver, ids)`` under this backend's placement.

        Returns the full wave's :class:`~repro.core.types.ExecResult` with
        one lane per entry of ``active_ids`` (see module docstring).
        """
        ...

    def snapshot(self, index: Any, write_locs: jax.Array, estimate: jax.Array,
                 incarnation: jax.Array, write_vals: jax.Array,
                 storage: jax.Array, n_locs: int) -> jax.Array:
        """MVMemory.snapshot: ``(n_locs,)`` final values over ``storage``."""
        ...

    def version_view(self, index: Any) -> jax.Array:
        """Global ``(n_regions,)`` region-version vector for this index."""
        ...

    def bump_versions(self, index: Any, dirty: jax.Array) -> Any:
        """Index with ``version`` bumped by a global ``(n_regions,)`` mask."""
        ...

    def trace_index_size(self, index: Any, write_locs: jax.Array) -> jax.Array:
        """() i32 live entry count of THIS index view (wave telemetry).

        Single-device backends report the global count; the dist backend
        reports the device-LOCAL count — per-wave region load balance is
        exactly what the trace wants to see (``repro.obs.trace``).
        """
        ...

    def guard_index_ok(self, index: Any, write_locs: jax.Array) -> jax.Array:
        """() bool structural health of THIS index view (guard checks).

        Called per wave by the engine's in-jit invariant sweep
        (``repro.guard.invariants``, ``guard_level >= 1``) with the
        post-update index and the full ``(n, W)`` write matrix it must
        index.  Backends check whatever their layout makes checkable —
        CSR backends verify occupancy == live write slots, monotone
        segment offsets, and occupancy <= capacity; the default is
        trivially healthy.
        """
        ...


class BackendDefaults:
    """Protocol-default batched/placement hooks (single-device layouts).

    Concrete backends inherit this; only the multi-device backend
    (:class:`repro.core.dist.backend.DistShardedBackend`) overrides the lot
    to change where regions live.
    """

    def resolve_batch(self, index, write_locs, estimate, incarnation,
                      locs, readers) -> ReadResolution:
        resolver = self.make_resolver(index, write_locs, estimate,
                                      incarnation)
        return jax.vmap(resolver)(locs, readers)

    def execute_routed(self, index, write_locs, estimate, incarnation,
                       active_ids, exec_fn):
        # Single-device identity: every lane executes here, reading through
        # the plain scalar resolver.
        return exec_fn(self.make_resolver(index, write_locs, estimate,
                                          incarnation), active_ids)

    def snapshot(self, index, write_locs, estimate, incarnation, write_vals,
                 storage, n_locs) -> jax.Array:
        locs = jnp.arange(n_locs, dtype=jnp.int32)
        readers = jnp.full((n_locs,), self.n_txns, jnp.int32)
        res = self.resolve_batch(index, write_locs, estimate, incarnation,
                                 locs, readers)
        return resolve_value(write_vals, storage, res, locs)

    def version_view(self, index) -> jax.Array:
        return index.version

    def bump_versions(self, index, dirty):
        return index._replace(version=index.version
                              + dirty.astype(jnp.int32))

    def trace_index_size(self, index, write_locs) -> jax.Array:
        # Every backend indexes exactly the block's live write slots, so
        # the slot count IS the entry count for the flat layouts; CSR
        # backends override with their own occupancy (the distinction that
        # matters once the index is device-local).
        return (write_locs != NO_LOC).sum(dtype=jnp.int32)

    def guard_index_ok(self, index, write_locs) -> jax.Array:
        # Layouts without a checkable structural invariant (the dense
        # last-writer table is definitionally consistent) report healthy;
        # the sorted/CSR backends override with real checks.
        return jnp.asarray(True)

    def trace_dirty_count(self, dirty) -> jax.Array:
        """() i32 count of THIS view's dirtied regions for the wave trace.

        ``dirty`` is ``update``'s global ``(n_regions,)`` mask; the dist
        backend narrows it to the device's own region span so the merged
        ``(D, cap)`` buffer shows where the write traffic actually landed.
        """
        return dirty.sum(dtype=jnp.int32)

    def trace_exec_lanes(self, active_ids, active_mask) -> jax.Array:
        """() i32 live lanes THIS view executed in the wave (telemetry).

        Single-device backends execute every live lane; the dist backend
        counts only the live lanes of its own slice of the partitioned wave
        (:meth:`execute_routed`) — the merged ``(D, cap)`` buffer is the
        execute-phase load-balance view.
        """
        return active_mask.sum(dtype=jnp.int32)


def dirty_from_delta(n_regions: int, region_of, old_write_locs: jax.Array,
                     new_write_locs: jax.Array) -> jax.Array:
    """(n_regions,) bool: regions touched by any live old or new write loc.

    This is the shared dirty-region rule: a changed txn's resolution footprint
    is exactly the union of its old entries (dropped — and the txn's estimate/
    incarnation stamps hang off them) and its new entries (inserted).
    """
    def touched(locs):
        flat = locs.reshape(-1)
        live = flat != NO_LOC
        region = jnp.where(live, region_of(flat), n_regions)  # dead -> dropped
        return jnp.zeros((n_regions,), jnp.bool_).at[region].set(True,
                                                                 mode="drop")

    return touched(old_write_locs) | touched(new_write_locs)


def update_by_rebuild(backend, index: Any, write_locs: jax.Array,
                      old_write_locs: jax.Array,
                      new_write_locs: jax.Array) -> tuple[Any, jax.Array]:
    """Reference ``update`` shim: full rebuild + version carry.

    Correct for every backend (the incremental paths must match it byte for
    byte); the flat ``sorted``/``dense`` backends use it directly so the
    engine's update code path is backend-agnostic.
    """
    dirty = dirty_from_delta(backend.n_regions, backend.region_of,
                             old_write_locs, new_write_locs)
    fresh = backend.build(write_locs)
    return fresh._replace(version=index.version + dirty.astype(jnp.int32)), \
        dirty


def finalize_resolution(found: jax.Array, txn_entry: jax.Array,
                        slot_entry: jax.Array, estimate: jax.Array,
                        incarnation: jax.Array) -> ReadResolution:
    """Shared tail of every index-lookup backend: stamp the found entry with
    the writer's ESTIMATE flag and incarnation, or the STORAGE sentinel."""
    writer = jnp.where(found, txn_entry, STORAGE)
    slot = jnp.where(found, slot_entry, 0)
    safe_writer = jnp.where(found, writer, 0)
    is_est = found & estimate[safe_writer]
    inc = jnp.where(found, incarnation[safe_writer], -1)
    return ReadResolution(found=found, writer=writer.astype(jnp.int32),
                          slot=slot.astype(jnp.int32),
                          inc=inc.astype(jnp.int32), is_estimate=is_est)


def resolve_value(write_vals: jax.Array, storage: jax.Array,
                  res: ReadResolution, loc: jax.Array) -> jax.Array:
    """Value of a resolution: writer's slot value, else storage[loc]."""
    safe_loc = jnp.clip(loc, 0, storage.shape[0] - 1)
    from_mv = write_vals[jnp.where(res.found, res.writer, 0),
                         jnp.where(res.found, res.slot, 0)]
    return jnp.where(res.found, from_mv, storage[safe_loc])

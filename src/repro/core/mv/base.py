"""MVBackend protocol: multi-version read resolution as a first-class subsystem.

The paper's MVMemory (Algorithm 2) answers one question: *a read of ``loc`` by
``tx_j`` resolves to the write of the highest writer ``tx_i`` with ``i < j``
that has a live entry at ``loc``* — plus the writer's incarnation stamp and
ESTIMATE flag.  Everything else in the engine (dependency registration,
validation, the commit frontier, snapshots) consumes only the answer, never
the data structure that produced it.

This module pins down that seam.  A backend is an object with two methods:

* ``build(write_locs) -> index``     — turn the block's ``(n, W)`` live write
  slots into whatever pytree of arrays the backend searches.  Called once at
  engine init and once per wave (after write sets change); the pytree rides
  in the ``lax.while_loop`` carry, so its structure and shapes must be fixed
  for a given :class:`~repro.core.types.EngineConfig`.
* ``make_resolver(index, write_locs, estimate, incarnation) -> resolver`` —
  close over the current MV state and return ``resolver(loc, reader) ->
  ReadResolution``, a scalar function the engine vmaps over reads, read-set
  validation rows, and the final snapshot.

Backends registered in :mod:`repro.core.mv` (``sorted`` / ``dense`` /
``sharded``) are interchangeable: the backend-equivalence property suite
(``tests/test_mv_backends.py``) checks byte-identical snapshots AND identical
abort/wave statistics, i.e. resolution-for-resolution agreement.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.types import STORAGE


class ReadResolution(NamedTuple):
    """Answer to one MV read (all fields are scalars; vmappable)."""

    found: jax.Array       # () bool — a lower writer exists (paper: status OK)
    writer: jax.Array      # () i32 — writer txn idx, or STORAGE
    slot: jax.Array        # () i32 — writer's write slot (for value gather)
    inc: jax.Array         # () i32 — writer's incarnation stamp (version)
    is_estimate: jax.Array  # () bool — entry is an ESTIMATE (paper: READ_ERROR)


#: ``resolver(loc, reader) -> ReadResolution`` — what ``make_resolver`` returns.
Resolver = Callable[[jax.Array, jax.Array], ReadResolution]


@runtime_checkable
class MVBackend(Protocol):
    """One multi-version index implementation (see module docstring)."""

    name: str

    def build(self, write_locs: jax.Array) -> Any:
        """(n, W) int32 live write locations -> index pytree (arrays only)."""
        ...

    def make_resolver(self, index: Any, write_locs: jax.Array,
                      estimate: jax.Array, incarnation: jax.Array) -> Resolver:
        """Close over the current MV state; return the per-read resolver."""
        ...


def finalize_resolution(found: jax.Array, txn_entry: jax.Array,
                        slot_entry: jax.Array, estimate: jax.Array,
                        incarnation: jax.Array) -> ReadResolution:
    """Shared tail of every index-lookup backend: stamp the found entry with
    the writer's ESTIMATE flag and incarnation, or the STORAGE sentinel."""
    writer = jnp.where(found, txn_entry, STORAGE)
    slot = jnp.where(found, slot_entry, 0)
    safe_writer = jnp.where(found, writer, 0)
    is_est = found & estimate[safe_writer]
    inc = jnp.where(found, incarnation[safe_writer], -1)
    return ReadResolution(found=found, writer=writer.astype(jnp.int32),
                          slot=slot.astype(jnp.int32),
                          inc=inc.astype(jnp.int32), is_estimate=is_est)


def resolve_value(write_vals: jax.Array, storage: jax.Array,
                  res: ReadResolution, loc: jax.Array) -> jax.Array:
    """Value of a resolution: writer's slot value, else storage[loc]."""
    safe_loc = jnp.clip(loc, 0, storage.shape[0] - 1)
    from_mv = write_vals[jnp.where(res.found, res.writer, 0),
                         jnp.where(res.found, res.slot, 0)]
    return jnp.where(res.found, from_mv, storage[safe_loc])

"""Sharded MV backend: per-region sorted indexes with shard-local int32 keys.

The flat ``sorted`` backend encodes a write slot as ``loc*(n_txns+1)+writer``
in int32, silently capping the location universe at ``~2^31/(n_txns+1)``
locations (≈2M at n=1024).  This backend partitions the universe into
``n_shards`` contiguous regions of ``shard_size = ceil(n_locs/n_shards)``
locations and keys each region *locally*:

    shard     = loc // shard_size
    local_loc = loc - shard*shard_size          # < shard_size
    key       = local_loc*(n_txns+1) + writer   # int32-safe per shard

so int32 keying survives arbitrarily large global universes as long as
``shard_size*(n_txns+1)`` fits — the overflow bound moves from the universe
size to the *region* size, which the operator controls via ``n_shards``
(:class:`~repro.core.types.EngineConfig` validates it at construction).

Layout: one ``(n_shards, n*W)`` row-sorted key matrix (each row padded with
+inf), built by one lexsort over (shard, local key) plus a scatter.  A read
gathers its shard row by ``loc // shard_size`` and binary-searches it — the
vmapped per-shard ``searchsorted`` is hand-rolled (:func:`row_searchsorted`)
so that under ``vmap`` each step is one scalar gather per lane instead of a
materialized ``(reads, n*W)`` row gather (the 10M-location snapshot would
otherwise allocate tens of GB).

Region partitioning by address range mirrors object-granularity STM designs
for smart contracts (Dickerson et al.; Anjana et al.) and is the structural
seam for multi-device execution: each region's index is independent, so a
future PR can ``shard_map`` regions across devices with resolution unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.mv.base import finalize_resolution
from repro.core.types import NO_LOC

_KEY_MAX = jnp.iinfo(jnp.int32).max
_I32_MAX = 2**31 - 1


def max_flat_locs(n_txns: int) -> int:
    """Largest universe (or shard) size whose keys ``loc*(n+1)+writer`` fit int32."""
    return (_I32_MAX - n_txns) // (n_txns + 1)


def shard_plan(n_locs: int, n_txns: int, n_shards: int = 0) -> tuple[int, int]:
    """Resolve ``(n_shards, shard_size)`` for a sharded universe.

    ``n_shards <= 0`` picks the fewest shards keeping shard-local keys in
    int32.  An explicit ``n_shards`` that leaves ``shard_size*(n_txns+1) +
    n_txns`` above int32 raises — the caller asked for regions too large to
    key.  ``n_shards`` never exceeds what ``n_locs`` can fill: 10 locations
    over 16 requested shards yield 10 single-location shards.
    """
    if n_locs < 1 or n_txns < 1:
        raise ValueError(f"need n_locs >= 1 and n_txns >= 1, got "
                         f"n_locs={n_locs}, n_txns={n_txns}")
    cap = max_flat_locs(n_txns)
    if n_shards <= 0:
        n_shards = -(-n_locs // cap)
    shard_size = -(-n_locs // n_shards)           # ceil division
    n_shards = -(-n_locs // shard_size)           # drop unreachable tail shards
    if shard_size > cap:
        raise ValueError(
            f"shard-local MV keys overflow int32: shard_size={shard_size} > "
            f"{cap} for n_locs={n_locs}, n_txns={n_txns}, "
            f"n_shards={n_shards}; raise n_shards (or leave it 0 for auto)")
    return n_shards, shard_size


class ShardedIndex(NamedTuple):
    """Per-shard sorted indexes, one row per region (arrays only).

    Every row holds ALL ``n*W`` slots' worth of capacity (a single region may
    absorb every write in the block); slots outside the row's region are
    padded to +inf, so each row is independently binary-searchable.
    """

    keys: jax.Array      # (n_shards, n*W) i32 row-sorted local keys, dead=+inf
    txn: jax.Array       # (n_shards, n*W) i32 writer txn per entry
    slot: jax.Array      # (n_shards, n*W) i32 writer's write slot per entry


def row_searchsorted(keys: jax.Array, row: jax.Array, q: jax.Array) -> jax.Array:
    """``searchsorted(keys[row], q, side='left')`` without materializing the row.

    Vmapped over (row, q) pairs this lowers to one scalar 2-D gather per
    binary-search step — O(log cap) gathers per read, no (reads, cap)
    intermediate.
    """
    cap = keys.shape[1]
    steps = max(cap, 1).bit_length() + 1   # halves [0, cap] to an empty interval

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2               # in-bounds whenever lo < hi
        go_right = (keys[row, mid] < q) & (lo < hi)
        go_left = (keys[row, mid] >= q) & (lo < hi)
        return (jnp.where(go_right, mid + 1, lo), jnp.where(go_left, mid, hi))

    lo = jnp.zeros_like(q)
    hi = jnp.full_like(q, cap)
    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


@dataclasses.dataclass(frozen=True)
class ShardedBackend:
    """MVBackend over region-partitioned sorted indexes (see module docstring)."""

    n_txns: int
    n_locs: int
    n_shards: int            # resolved (positive) shard count
    shard_size: int          # ceil(n_locs / n_shards); local keys fit int32
    name: str = dataclasses.field(default="sharded", init=False)

    @classmethod
    def from_universe(cls, n_txns: int, n_locs: int,
                      n_shards: int = 0) -> "ShardedBackend":
        n_shards, shard_size = shard_plan(n_locs, n_txns, n_shards)
        return cls(n_txns=n_txns, n_locs=n_locs, n_shards=n_shards,
                   shard_size=shard_size)

    def build(self, write_locs: jax.Array) -> ShardedIndex:
        n, w = write_locs.shape
        if write_locs.dtype != jnp.int32:
            raise TypeError(f"write_locs must be int32, got {write_locs.dtype}")
        total = n * w
        flat = write_locs.reshape(-1)
        writer = jnp.broadcast_to(
            jnp.arange(n, dtype=jnp.int32)[:, None], (n, w)).reshape(-1)
        slot = jnp.broadcast_to(
            jnp.arange(w, dtype=jnp.int32)[None, :], (n, w)).reshape(-1)
        live = flat != NO_LOC
        # Dead slots route to the out-of-bounds row n_shards: they sort last
        # and the scatter drops them.
        shard = jnp.where(live, flat // self.shard_size, self.n_shards)
        local = flat - shard * self.shard_size
        lkey = jnp.where(live, local * (self.n_txns + 1) + writer, _KEY_MAX)
        order = jnp.lexsort((lkey, shard))        # by shard, then local key
        shard_s, lkey_s = shard[order], lkey[order]
        starts = jnp.searchsorted(shard_s,
                                  jnp.arange(self.n_shards, dtype=jnp.int32))
        pos = (jnp.arange(total, dtype=jnp.int32)
               - starts[jnp.clip(shard_s, 0, self.n_shards - 1)])
        pad = jnp.full((self.n_shards, total), _KEY_MAX, jnp.int32)
        zeros = jnp.zeros((self.n_shards, total), jnp.int32)
        return ShardedIndex(
            keys=pad.at[shard_s, pos].set(lkey_s, mode="drop"),
            txn=zeros.at[shard_s, pos].set(writer[order], mode="drop"),
            slot=zeros.at[shard_s, pos].set(slot[order], mode="drop"),
        )

    def make_resolver(self, index: ShardedIndex, write_locs: jax.Array,
                      estimate: jax.Array, incarnation: jax.Array):
        n1 = self.n_txns + 1

        def resolver(loc, reader):
            in_universe = (loc >= 0) & (loc < self.n_locs)
            shard = jnp.clip(loc // self.shard_size, 0, self.n_shards - 1)
            local = loc - shard * self.shard_size
            # Highest local key strictly below local*(n+1)+reader, same loc.
            pos = row_searchsorted(index.keys, shard, local * n1 + reader) - 1
            safe = jnp.maximum(pos, 0)
            key = index.keys[shard, safe]
            found = (pos >= 0) & (key // n1 == local) & in_universe
            return finalize_resolution(found, index.txn[shard, safe],
                                       index.slot[shard, safe], estimate,
                                       incarnation)

        return resolver

"""Sharded MV backend: CSR-flat per-region sorted index, shard-local keys.

The flat ``sorted`` backend encodes a write slot as ``loc*(n_txns+1)+writer``
in int32, silently capping the location universe at ``~2^31/(n_txns+1)``
locations (≈2M at n=1024).  This backend partitions the universe into
``n_shards`` contiguous regions of ``shard_size = ceil(n_locs/n_shards)``
locations and keys each region *locally*:

    shard     = loc // shard_size
    local_loc = loc - shard*shard_size          # < shard_size
    key       = local_loc*(n_txns+1) + writer   # int32-safe per shard

so int32 keying survives arbitrarily large global universes as long as
``shard_size*(n_txns+1)`` fits — the overflow bound moves from the universe
size to the *region* size, which the operator controls via ``n_shards``
(:class:`~repro.core.types.EngineConfig` validates it at construction).

Layout (CSR over regions): ONE ``(cap,)`` entry list (``cap = n*W``) sorted
by ``(shard, local key)``, live entries first, dead slots normalized to a
``(KEY_MAX, 0)`` tail; a ``(n_shards+1,)`` ``starts`` array bounds each
region's segment.  A read gathers its segment bounds and binary-searches
inside them (:func:`segment_searchsorted` — one scalar gather per bisection
step under ``vmap``, never a materialized row).  Writer txn and write slot
are packed into one int32 (``txn*W + slot``), so the whole index is two flat
int32 arrays + the tiny offsets — S× smaller than a per-region row matrix
and, more importantly, *maintainable by streaming ops*:

:meth:`ShardedBackend.update` applies a wave's write-set delta in O(cap)
streaming work + O(window*W · log cap) searches, with NO O(cap)-element sort
and NO O(cap)-element scatter (XLA CPU scatters cost ~100ns/element — the
measured reason a row-matrix delta merge LOST to its own rebuild).  All
positional bookkeeping happens on the ``window*W`` event lists; the flat
output is then produced by one cumsum (the merge offset array) and two
clamp-gathers.  See the method docstring for the event algebra.

Region partitioning by address range mirrors object-granularity STM designs
for smart contracts (Dickerson et al.; Anjana et al.) and is the structural
seam for multi-device execution: each region's segment is independent, so a
future PR can ``shard_map`` regions across devices with resolution unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.mv.base import (BackendDefaults, dirty_from_delta,
                                finalize_resolution)
from repro.core.types import NO_LOC

_KEY_MAX = jnp.iinfo(jnp.int32).max
_I32_MAX = 2**31 - 1


def max_flat_locs(n_txns: int) -> int:
    """Largest universe (or shard) size whose keys ``loc*(n+1)+writer`` fit int32."""
    return (_I32_MAX - n_txns) // (n_txns + 1)


def shard_plan(n_locs: int, n_txns: int, n_shards: int = 0) -> tuple[int, int]:
    """Resolve ``(n_shards, shard_size)`` for a sharded universe.

    ``n_shards <= 0`` picks the fewest shards keeping shard-local keys in
    int32.  An explicit ``n_shards`` that leaves ``shard_size*(n_txns+1) +
    n_txns`` above int32 raises — the caller asked for regions too large to
    key.  ``n_shards`` never exceeds what ``n_locs`` can fill: 10 locations
    over 16 requested shards yield 10 single-location shards.
    """
    if n_locs < 1 or n_txns < 1:
        raise ValueError(f"need n_locs >= 1 and n_txns >= 1, got "
                         f"n_locs={n_locs}, n_txns={n_txns}")
    cap = max_flat_locs(n_txns)
    if n_shards <= 0:
        n_shards = -(-n_locs // cap)
    shard_size = -(-n_locs // n_shards)           # ceil division
    n_shards = -(-n_locs // shard_size)           # drop unreachable tail shards
    if shard_size > cap:
        raise ValueError(
            f"shard-local MV keys overflow int32: shard_size={shard_size} > "
            f"{cap} for n_locs={n_locs}, n_txns={n_txns}, "
            f"n_shards={n_shards}; raise n_shards (or leave it 0 for auto)")
    return n_shards, shard_size


class ShardedIndex(NamedTuple):
    """CSR-flat per-region sorted index (arrays only).

    ``keys[starts[s]:starts[s+1]]`` is region ``s``'s ascending local-key
    segment; all dead capacity is one normalized ``(KEY_MAX, 0)`` tail after
    ``starts[n_shards]``.  ``packed = writer*W + slot`` (W = max_writes).
    """

    keys: jax.Array      # (n*W,) i32 segment-sorted local keys, dead = +inf
    packed: jax.Array    # (n*W,) i32 writer*W + slot per entry, dead = 0
    starts: jax.Array    # (n_shards+1,) i32 segment offsets; [-1] = total live
    version: Any = None  # (n_shards,) i32 region version (bumped when dirty)


def segment_searchsorted(keys: jax.Array, lo: jax.Array, hi: jax.Array,
                         q: jax.Array) -> jax.Array:
    """``lo + searchsorted(keys[lo:hi], q, side='left')`` without slicing.

    Vmapped over (lo, hi, q) triples this lowers to one scalar gather per
    bisection step — O(log cap) gathers per read, no (reads, cap)
    intermediate.  This is the region-resolve hot loop the
    ``mv_region_resolve`` Pallas kernel batches on TPU.
    """
    cap = keys.shape[0]
    steps = max(cap, 1).bit_length() + 1   # halves [lo, hi] to empty

    def body(_, lohi):
        lo_, hi_ = lohi
        mid = (lo_ + hi_) // 2             # in-bounds whenever lo_ < hi_
        go_right = (keys[mid] < q) & (lo_ < hi_)
        go_left = (keys[mid] >= q) & (lo_ < hi_)
        return (jnp.where(go_right, mid + 1, lo_),
                jnp.where(go_left, mid, hi_))

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def select_search(resolver_impl: str):
    """Segment-search implementation behind ``EngineConfig.resolver_impl``.

    ``'pallas'`` batches the segment binary search on TPU
    (kernels/mv_region_resolve) via ``custom_vmap``: scalar calls still run
    :func:`segment_searchsorted`, but vmapped reads hit the Pallas kernel.
    Lazy import: the kernel package depends on this module for its XLA
    reference.  Shared by :class:`ShardedBackend` and the multi-device
    backend (:mod:`repro.core.dist`), whose owner-side answering is the same
    per-shard search.
    """
    if resolver_impl == "pallas":
        from repro.kernels.mv_region_resolve import ops as rr_ops
        return rr_ops.batchable_segment_searchsorted
    if resolver_impl == "xla":
        return segment_searchsorted
    raise ValueError(f"unknown resolver_impl {resolver_impl!r}; "
                     f"expected 'xla' or 'pallas'")


def row_searchsorted(keys: jax.Array, row: jax.Array, q: jax.Array) -> jax.Array:
    """``searchsorted(keys[row], q, side='left')`` for a (rows, cap) matrix.

    Legacy 2-D form of :func:`segment_searchsorted` (the PR 3 row-matrix
    layout); kept for its tests and as a reference oracle.
    """
    cap = keys.shape[1]
    flat = keys.reshape(-1)
    lo = row * cap
    return segment_searchsorted(flat, lo, lo + cap, q) - lo


def _encode(write_locs: jax.Array, txn_ids: jax.Array, n_txns: int,
            shard_size: int, n_shards: int):
    """(rows, W) locs + (rows,) writer ids -> sorted (shard, key, packed).

    Dead slots (NO_LOC or writer >= n_txns) get ``(n_shards, KEY_MAX, 0)``
    and sort last; ``jnp.lexsort`` is stable, so equal keys (one txn writing
    one loc from two slots) stay in slot-minor order — the tie order every
    build and update below must share for byte-identity.
    """
    rows, w = write_locs.shape
    flat = write_locs.reshape(-1)
    writer = jnp.broadcast_to(txn_ids[:, None], (rows, w)).reshape(-1)
    slot = jnp.broadcast_to(
        jnp.arange(w, dtype=jnp.int32)[None, :], (rows, w)).reshape(-1)
    live = (flat != NO_LOC) & (writer >= 0) & (writer < n_txns)
    shard = jnp.where(live, flat // shard_size, n_shards)
    lkey = jnp.where(live, (flat - shard * shard_size) * (n_txns + 1) + writer,
                     _KEY_MAX)
    order = jnp.lexsort((lkey, shard))
    packed = jnp.where(live, writer * w + slot, 0)
    return shard[order], lkey[order], packed[order], live[order]


@dataclasses.dataclass(frozen=True)
class ShardedBackend(BackendDefaults):
    """MVBackend over the CSR-flat region index (see module docstring)."""

    n_txns: int
    n_locs: int
    n_shards: int            # resolved (positive) shard count
    shard_size: int          # ceil(n_locs / n_shards); local keys fit int32
    resolver_impl: str = "xla"   # 'xla' (segment_searchsorted) | 'pallas'
    name: str = dataclasses.field(default="sharded", init=False)

    @classmethod
    def from_universe(cls, n_txns: int, n_locs: int, n_shards: int = 0,
                      resolver_impl: str = "xla") -> "ShardedBackend":
        n_shards, shard_size = shard_plan(n_locs, n_txns, n_shards)
        return cls(n_txns=n_txns, n_locs=n_locs, n_shards=n_shards,
                   shard_size=shard_size, resolver_impl=resolver_impl)

    @property
    def n_regions(self) -> int:
        return self.n_shards

    def region_of(self, locs: jax.Array) -> jax.Array:
        """Location -> region id.  NO_LOC maps into range (callers mask it)."""
        return jnp.clip(locs // self.shard_size, 0, self.n_shards - 1)

    def trace_index_size(self, index: ShardedIndex,
                         write_locs: jax.Array) -> jax.Array:
        """CSR occupancy: ``starts[-1]`` is the total live entry count."""
        return index.starts[-1]

    def guard_index_ok(self, index: ShardedIndex,
                       write_locs: jax.Array) -> jax.Array:
        """CSR structural health: segment offsets monotone from 0,
        occupancy within capacity AND exactly the live write-slot count
        (the conservation law the incremental event merge must preserve
        wave over wave), segment keys ascending with the dead +inf tail
        after ``starts[-1]``."""
        live = (write_locs != NO_LOC).sum(dtype=jnp.int32)
        occ = index.starts[-1]
        cap = index.keys.shape[0]
        offsets_ok = ((index.starts[0] == 0) & (occ <= cap)
                      & (jnp.diff(index.starts) >= 0).all())
        pos = jnp.arange(cap, dtype=jnp.int32)
        # Keys ascend within each segment; across a segment boundary the
        # local keys may legally drop, so compare only positions whose
        # predecessor is in the same segment.
        seg = jnp.searchsorted(index.starts[1:-1], pos, side="right")
        same_seg = (pos > 0) & (seg == jnp.roll(seg, 1)) & (pos < occ)
        keys_ok = (~same_seg | (index.keys >= jnp.roll(index.keys, 1))).all()
        dead_ok = ((pos < occ) | (index.keys == _KEY_MAX)).all()
        return offsets_ok & (occ == live) & keys_ok & dead_ok

    def build(self, write_locs: jax.Array) -> ShardedIndex:
        n, w = write_locs.shape
        if write_locs.dtype != jnp.int32:
            raise TypeError(f"write_locs must be int32, got {write_locs.dtype}")
        shard_s, lkey_s, packed_s, _ = _encode(
            write_locs, jnp.arange(n, dtype=jnp.int32), self.n_txns,
            self.shard_size, self.n_shards)
        starts = jnp.searchsorted(
            shard_s, jnp.arange(self.n_shards + 1, dtype=jnp.int32),
            side="left").astype(jnp.int32)
        return ShardedIndex(keys=lkey_s, packed=packed_s, starts=starts,
                            version=jnp.zeros((self.n_shards,), jnp.int32))

    def update(self, index: ShardedIndex, write_locs: jax.Array,
               txn_ids: jax.Array, old_write_locs: jax.Array,
               new_write_locs: jax.Array) -> tuple[ShardedIndex, jax.Array]:
        """Event-merge delta: O(wave · log) bookkeeping, O(cap) streaming.

        The merged flat list differs from the old one by at most
        ``window*W`` dropped entries (the changed txns' stale keys, which sit
        exactly at ``old_write_locs``) and ``window*W`` inserted ones — so
        instead of re-sorting, the update computes the two event lists and
        derives every output position from ONE prefix-summed offset array:

        * stale events: each old live loc resolves (segment search) to its
          flat position ``p``; since the searches are issued in sorted
          (shard, key) order, ``p`` comes out ascending and ``a = p - rank``
          is the entry's *kept-rank* boundary (duplicate keys — one txn, one
          loc, two slots — are disambiguated by their stable query rank).
        * new events: each new live key's insertion point ``q`` (segment
          search into the OLD list) gives its kept-boundary
          ``c = q - #stale(< q)``; with ``r`` its rank among the wave's
          sorted new entries, its output position is ``t = c + r`` (survivors
          vs. new entries have disjoint writers, so there are no cross ties).
        * a stale skip at kept-rank ``a`` fires at output position
          ``u = a + #new(c <= a)``.

        Then ``src[j] = j + Σ[u <= j] - Σ[t <= j]`` — one small event
        scatter + one ``(cap,)`` cumsum — and the output arrays are
        ``where(is_new, new_vals, old[src])``: two clamp-gathers, with
        ``src >= cap`` (net shrink) drawing the normalized dead pad.  Output
        bytes match :meth:`build` on the post-wave write sets exactly;
        ``tests/test_mv_incremental.py`` property-tests the identity, and the
        engine's rebuild path stays available as ``mv_update='rebuild'``.

        Contract: ``old_write_locs`` must be the changed txns' true
        pre-update live write sets (that is what makes the stale searches
        exact and ``dirty_regions`` cover every mutated segment).
        """
        n, w = write_locs.shape
        S, cap = self.n_shards, n * w
        wn = txn_ids.shape[0] * w
        i32 = jnp.int32

        # -- stale events -------------------------------------------------
        os_, okey, _, olive = _encode(old_write_locs, txn_ids, self.n_txns,
                                      self.shard_size, self.n_shards)
        lo = index.starts[jnp.clip(os_, 0, S - 1)]
        hi = index.starts[jnp.clip(os_, 0, S - 1) + 1]
        p = jax.vmap(lambda l, h, q: segment_searchsorted(index.keys, l, h, q)
                     )(lo, hi, okey)
        # duplicate (shard, key) queries hit adjacent entries: offset by the
        # rank within the equal-query group (stable order = slot-minor)
        iw = jnp.arange(wn, dtype=i32)
        grp_new = (iw == 0) | (os_ != jnp.roll(os_, 1)) | \
            (okey != jnp.roll(okey, 1))
        dup = iw - jax.lax.cummax(jnp.where(grp_new, iw, 0))
        p = jnp.where(olive, p + dup, cap)            # dead -> inert tail
        a = p - jnp.cumsum(olive.astype(i32)) + olive  # kept-rank boundary

        # -- new events ---------------------------------------------------
        ns_, nkey, npack, nlive = _encode(new_write_locs, txn_ids,
                                          self.n_txns, self.shard_size,
                                          self.n_shards)
        lo = index.starts[jnp.clip(ns_, 0, S - 1)]
        hi = index.starts[jnp.clip(ns_, 0, S - 1) + 1]
        q = jax.vmap(lambda l, h, k: segment_searchsorted(index.keys, l, h, k)
                     )(lo, hi, nkey)
        c = jnp.where(nlive, q - jnp.searchsorted(p, q, side="left"), cap + wn)
        r = jnp.cumsum(nlive.astype(i32)) - 1
        t = jnp.where(nlive, c + r, cap + wn)          # new output positions
        u = jnp.where(olive, a + jnp.searchsorted(c, a, side="right"),
                      cap + wn)                        # stale skip positions

        # -- merge offset + output streams --------------------------------
        delta = jnp.zeros((cap + 1,), i32).at[u].add(1, mode="drop") \
                                          .at[t].add(-1, mode="drop")
        src = jnp.arange(cap, dtype=i32) + jnp.cumsum(delta[:cap])
        is_new = jnp.zeros((cap,), jnp.bool_).at[t].set(True, mode="drop")
        new_id = jnp.zeros((cap,), i32).at[t].set(iw, mode="drop")
        srcc = jnp.clip(src, 0, cap - 1)
        run_off = src >= cap                           # net shrink: dead pad
        out_keys = jnp.where(is_new, nkey[new_id],
                             jnp.where(run_off, _KEY_MAX, index.keys[srcc]))
        out_pack = jnp.where(is_new, npack[new_id],
                             jnp.where(run_off, 0, index.packed[srcc]))

        # -- segment offsets + dirty regions ------------------------------
        dsize = jnp.zeros((S,), i32) \
            .at[os_].add(-olive.astype(i32), mode="drop") \
            .at[ns_].add(nlive.astype(i32), mode="drop")
        starts = index.starts.at[1:].add(jnp.cumsum(dsize))
        dirty = dirty_from_delta(S, self.region_of, old_write_locs,
                                 new_write_locs)
        return ShardedIndex(
            keys=out_keys, packed=out_pack, starts=starts,
            version=index.version + dirty.astype(i32)), dirty

    def make_resolver(self, index: ShardedIndex, write_locs: jax.Array,
                      estimate: jax.Array, incarnation: jax.Array):
        n1 = self.n_txns + 1
        w = write_locs.shape[1]
        search = select_search(self.resolver_impl)

        def resolver(loc, reader):
            in_universe = (loc >= 0) & (loc < self.n_locs)
            shard = jnp.clip(loc // self.shard_size, 0, self.n_shards - 1)
            local = loc - shard * self.shard_size
            lo = index.starts[shard]
            hi = index.starts[shard + 1]
            # Highest local key strictly below local*(n+1)+reader, same loc.
            pos = search(index.keys, lo, hi, local * n1 + reader) - 1
            safe = jnp.clip(pos, 0, index.keys.shape[0] - 1)
            key = index.keys[safe]
            entry = index.packed[safe]
            found = (pos >= lo) & (key // n1 == local) & in_universe
            return finalize_resolution(found, entry // w, entry % w,
                                       estimate, incarnation)

        return resolver

"""Benchmark transaction programs (paper §4.1).

* ``p2p``       — the paper's peer-to-peer payment: pick two accounts, move a
  random amount.  Parameterized read/write profile: Diem p2p ≈ 21 reads /
  4 writes (balances + sequence numbers + chain-config reads), Aptos p2p ≈
  8 reads / 5 writes.  Chain-config locations are shared *read-only* state and
  never conflict; balances + sequence numbers conflict under small account sets.
* ``indirect``  — a pointer-chasing contract: read an index cell, then
  read-modify-write the account it points at (dynamic read set: the hot
  location is only discoverable *during* execution — the case Bohm cannot
  precompute).
* ``admission`` — serving-admission transactions used by the serving example:
  allocate KV-cache pages from a shared free-list head and charge a tenant
  quota; conditional write set (rejected requests write nothing).

Location universes are laid out as flat int32 ids:
  account a: balance at 2a, sequence number at 2a+1; chain config occupies the
  tail of the universe.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import EngineConfig

CHAIN_CFG_READS_DIEM = 15   # 21 total reads = 15 cfg + 2 balances + 2 seqnos + 2 frozen-flags
CHAIN_CFG_READS_APTOS = 4   # 8 total reads  = 4 cfg + 2 balances + 2 seqnos


def zipf_choice(rng: np.random.Generator, n: int, size: int,
                s: float = 0.0) -> np.ndarray:
    """Sample ``size`` ids from [0, n) with Zipf(s) rank weights.

    ``P(k) ∝ 1/(k+1)^s`` — id 0 is the hottest.  ``s=0`` falls back to the
    exact uniform draw the generators used before the knob existed (so
    default blocks are bit-identical across versions).  With a skew knob,
    contention is governed by hotness rather than universe size: a 10M-account
    universe at ``s≈1`` still funnels most traffic through a few thousand hot
    accounts — the paper's contended-vs-uncontended sweep at realistic
    account counts.
    """
    if s <= 0.0:
        return rng.integers(0, n, size)
    return np.searchsorted(_zipf_cdf(n, s), rng.random(size),
                           side="right").astype(np.int64)


@functools.lru_cache(maxsize=8)
def _zipf_cdf(n: int, s: float) -> np.ndarray:
    """Normalized Zipf(s) CDF over n ranks — O(n) and ~8n bytes, so memoized
    (multi-million-account generators draw src and dst from the same CDF)."""
    cdf = np.cumsum(np.arange(1, n + 1, dtype=np.float64) ** -s)
    cdf /= cdf[-1]
    cdf.setflags(write=False)
    return cdf


@dataclasses.dataclass(frozen=True)
class P2PSpec:
    n_accounts: int
    cfg_reads: int = CHAIN_CFG_READS_APTOS   # 'aptos' profile by default
    write_seqno: bool = True                 # Diem/Aptos both bump sender+receiver meta

    @property
    def n_locs(self) -> int:
        return 2 * self.n_accounts + self.cfg_reads

    @property
    def max_reads(self) -> int:
        return self.cfg_reads + 4

    @property
    def max_writes(self) -> int:
        return 4 if self.write_seqno else 2


def p2p_program(spec: P2PSpec):
    """(params, ctx) transaction body; params = dict(src, dst, amount)."""
    cfg_base = 2 * spec.n_accounts

    def txn(p, ctx):
        # chain-config verification reads (read-only shared state).
        for k in range(spec.cfg_reads):
            ctx.read(cfg_base + k)
        src_bal = ctx.read(2 * p["src"])
        dst_bal = ctx.read(2 * p["dst"])
        ok = src_bal >= p["amount"]            # conditional => dynamic write set
        ctx.write(2 * p["src"], src_bal - p["amount"], enabled=ok)
        ctx.write(2 * p["dst"], dst_bal + p["amount"], enabled=ok)
        if spec.write_seqno:
            src_seq = ctx.read(2 * p["src"] + 1)
            dst_seq = ctx.read(2 * p["dst"] + 1)
            ctx.write(2 * p["src"] + 1, src_seq + 1)
            ctx.write(2 * p["dst"] + 1, dst_seq + 1, enabled=ok)

    return txn


def make_p2p_block(spec: P2PSpec, n_txns: int, seed: int = 0,
                   init_balance: int = 10**6, zipf_s: float = 0.0):
    """Random p2p block + storage, mirroring the paper's generator.

    ``zipf_s > 0`` draws both endpoints Zipf-skewed (see :func:`zipf_choice`);
    0 keeps the original uniform draw bit-for-bit.
    """
    rng = np.random.default_rng(seed)
    src = zipf_choice(rng, spec.n_accounts, n_txns, zipf_s)
    # dst != src, as in the paper ("two different accounts").
    if zipf_s > 0.0:
        dst = zipf_choice(rng, spec.n_accounts, n_txns, zipf_s)
        dst = np.where(dst == src, (dst + 1) % spec.n_accounts, dst)
    else:
        dst = (src + rng.integers(1, max(spec.n_accounts, 2), n_txns)) \
            % spec.n_accounts
    if spec.n_accounts == 1:
        dst = src
    amount = rng.integers(1, 100, n_txns)
    params = {
        "src": jnp.asarray(src, jnp.int32),
        "dst": jnp.asarray(dst, jnp.int32),
        "amount": jnp.asarray(amount, jnp.int32),
    }
    storage = np.zeros(spec.n_locs, np.int32)
    storage[0:2 * spec.n_accounts:2] = init_balance
    storage[2 * spec.n_accounts:] = rng.integers(1, 1000, spec.cfg_reads)
    return params, jnp.asarray(storage)


def p2p_engine_config(spec: P2PSpec, n_txns: int, window: int = 32,
                      **kw) -> EngineConfig:
    return EngineConfig(n_txns=n_txns, n_locs=spec.n_locs,
                        max_reads=spec.max_reads, max_writes=spec.max_writes,
                        window=window, **kw)


# ---------------------------------------------------------------------------
# Pointer-indirection workload: dynamic read locations.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IndirectSpec:
    n_slots: int          # pointer cells [0, n_slots) -> targets [n_slots, 2*n_slots)

    @property
    def n_locs(self) -> int:
        return 2 * self.n_slots

    max_reads: int = 3
    max_writes: int = 2


def indirect_program(spec: IndirectSpec):
    def txn(p, ctx):
        target = ctx.read(p["slot"])           # hop 1: discover the target
        val = ctx.read(target)                 # hop 2: dynamic location
        ctx.write(target, val + p["delta"])    # RMW on the discovered cell
        # occasionally repoint the slot -> lower txns change higher txns' read sets
        ctx.write(p["slot"], p["new_target"], enabled=p["repoint"] != 0)
    return txn


def make_indirect_block(spec: IndirectSpec, n_txns: int, seed: int = 0,
                        repoint_prob: float = 0.2, zipf_s: float = 0.0):
    rng = np.random.default_rng(seed)
    params = {
        "slot": jnp.asarray(zipf_choice(rng, spec.n_slots, n_txns, zipf_s),
                            jnp.int32),
        "delta": jnp.asarray(rng.integers(1, 50, n_txns), jnp.int32),
        "new_target": jnp.asarray(
            rng.integers(spec.n_slots, 2 * spec.n_slots, n_txns), jnp.int32),
        "repoint": jnp.asarray(
            rng.random(n_txns) < repoint_prob, jnp.int32),
    }
    storage = np.zeros(spec.n_locs, np.int32)
    storage[:spec.n_slots] = rng.integers(spec.n_slots, 2 * spec.n_slots,
                                          spec.n_slots)
    return params, jnp.asarray(storage)


def indirect_engine_config(spec: IndirectSpec, n_txns: int, window: int = 32,
                           **kw) -> EngineConfig:
    return EngineConfig(n_txns=n_txns, n_locs=spec.n_locs,
                        max_reads=spec.max_reads, max_writes=spec.max_writes,
                        window=window, **kw)


# ---------------------------------------------------------------------------
# Serving-admission workload (used by examples/serve_blockstm.py).
# Locations: 0 = free-page head pointer; 1..T = per-tenant used-quota;
# T+1..T+G = per-sequence-group page-count.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdmissionSpec:
    n_tenants: int
    n_groups: int
    total_pages: int
    quota_per_tenant: int

    @property
    def n_locs(self) -> int:
        return 1 + self.n_tenants + self.n_groups

    max_reads: int = 3
    max_writes: int = 3


def admission_program(spec: AdmissionSpec):
    def txn(p, ctx):
        head = ctx.read(0)                         # free-list head (hot!)
        used = ctx.read(1 + p["tenant"])
        grp = ctx.read(1 + spec.n_tenants + p["group"])
        fits = (head + p["pages"] <= spec.total_pages) & \
               (used + p["pages"] <= spec.quota_per_tenant)
        ctx.write(0, head + p["pages"], enabled=fits)
        ctx.write(1 + p["tenant"], used + p["pages"], enabled=fits)
        ctx.write(1 + spec.n_tenants + p["group"], grp + p["pages"],
                  enabled=fits)
    return txn


def make_admission_block(spec: AdmissionSpec, n_txns: int, seed: int = 0,
                         zipf_s: float = 0.0):
    rng = np.random.default_rng(seed)
    params = {
        "tenant": jnp.asarray(zipf_choice(rng, spec.n_tenants, n_txns, zipf_s),
                              jnp.int32),
        "group": jnp.asarray(zipf_choice(rng, spec.n_groups, n_txns, zipf_s),
                             jnp.int32),
        "pages": jnp.asarray(rng.integers(1, 8, n_txns), jnp.int32),
    }
    storage = jnp.zeros(spec.n_locs, jnp.int32)
    return params, storage


def admission_engine_config(spec: AdmissionSpec, n_txns: int, window: int = 32,
                            **kw) -> EngineConfig:
    return EngineConfig(n_txns=n_txns, n_locs=spec.n_locs,
                        max_reads=spec.max_reads, max_writes=spec.max_writes,
                        window=window, **kw)


# ---------------------------------------------------------------------------
# Mixed-contract blocks (bytecode VM): all three families in ONE block.
# The paper evaluates adversarially mixed workloads; the Python DSL cannot
# express them (vmap needs one traced program), the bytecode VM can — each
# txn carries its own (code, args).  Location regions are disjoint:
#   [0, p2p.n_locs) | [.., +indirect.n_locs) | [.., +admission.n_locs).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MixedSpec:
    p2p: P2PSpec = P2PSpec(n_accounts=100)
    indirect: IndirectSpec = IndirectSpec(n_slots=50)
    admission: AdmissionSpec = AdmissionSpec(
        n_tenants=3, n_groups=8, total_pages=4096, quota_per_tenant=2048)
    ratios: tuple = (1.0, 1.0, 1.0)   # p2p : indirect : admission

    @property
    def n_locs(self) -> int:
        return self.p2p.n_locs + self.indirect.n_locs + self.admission.n_locs


def scale_mixed_spec(spec: MixedSpec, n_locs: int) -> MixedSpec:
    """Grow ``spec`` until its universe fills ``n_locs`` locations.

    The extra space is split ~3:1 between p2p accounts and indirect pointer
    slots (both cost 2 locations apiece); the admission region keeps its
    size.  Up to one tail location may stay unused when parity doesn't work
    out — the engine config still spans the full ``n_locs``.
    """
    if n_locs < spec.n_locs:
        raise ValueError(f"n_locs={n_locs} is smaller than the spec's "
                         f"universe ({spec.n_locs} locations)")
    extra = n_locs - spec.n_locs
    add_slots = extra // 8
    add_accounts = (extra - 2 * add_slots) // 2
    return dataclasses.replace(
        spec,
        p2p=dataclasses.replace(
            spec.p2p, n_accounts=spec.p2p.n_accounts + add_accounts),
        indirect=dataclasses.replace(
            spec.indirect, n_slots=spec.indirect.n_slots + add_slots))


def make_mixed_block(spec: MixedSpec, n_txns: int, seed: int = 0,
                     init_balance: int = 10**6, repoint_prob: float = 0.2,
                     window: int = 32, n_locs: int | None = None,
                     zipf_s: float = 0.0, **cfg_kw):
    """Heterogeneous block: the three contract families interleaved at
    ``spec.ratios``.  Returns ``(vm, params, storage, cfg)`` where ``params``
    carries per-txn ``(code, args)`` — one jitted ``make_executor(vm, cfg)``
    runs ANY mix with zero recompiles.

    ``n_locs`` (up to 10M+) grows the universe to a realistic account count
    (:func:`scale_mixed_spec`); at that scale use ``backend='sharded'`` in
    ``cfg_kw`` — flat int32 MV keys overflow.  ``zipf_s`` skews the location
    draw (:func:`zipf_choice`), so contention is governed by hotness rather
    than universe size.
    """
    from repro.bytecode import compile as BC

    if n_locs is not None:
        spec = scale_mixed_spec(spec, n_locs)
    total_locs = max(n_locs or 0, spec.n_locs)
    rng = np.random.default_rng(seed)
    p2p_base = 0
    ind_base = spec.p2p.n_locs
    adm_base = ind_base + spec.indirect.n_locs

    progs = BC.pad_common([
        BC.compile_p2p(spec.p2p, loc_base=p2p_base),
        BC.compile_indirect(spec.indirect, loc_base=ind_base),
        BC.compile_admission(spec.admission, loc_base=adm_base),
    ])
    n_params = max(p.n_params for p in progs)
    fam_code = np.stack([p.code for p in progs])          # (3, L, 4)

    # Reuse the single-family generators (one derived seed each) so the mixed
    # distributions can never drift from the homogeneous ones.
    p2p_params, p2p_storage = make_p2p_block(
        spec.p2p, n_txns, seed=seed, init_balance=init_balance, zipf_s=zipf_s)
    ind_params, ind_storage = make_indirect_block(
        spec.indirect, n_txns, seed=seed + 1, repoint_prob=repoint_prob,
        zipf_s=zipf_s)
    adm_params, adm_storage = make_admission_block(
        spec.admission, n_txns, seed=seed + 2, zipf_s=zipf_s)
    # Pointer VALUES in the indirect family are absolute locations in the
    # mixed universe: offset both the stored pointers and new_target params.
    ind_params = dict(ind_params,
                      new_target=jnp.asarray(ind_params["new_target"])
                      + ind_base)
    ind_storage = np.asarray(ind_storage).copy()
    ind_storage[:spec.indirect.n_slots] += ind_base

    fam_args = [BC.pack_args({k: np.asarray(v) for k, v in p.items()},
                             order, n_params)
                for p, order in ((p2p_params, BC.P2P_ARGS),
                                 (ind_params, BC.INDIRECT_ARGS),
                                 (adm_params, BC.ADMISSION_ARGS))]

    ratios = np.asarray(spec.ratios, np.float64)
    if ratios.shape != (3,) or (ratios < 0).any() or ratios.sum() <= 0:
        raise ValueError(f"ratios must be 3 non-negative weights with a "
                         f"positive sum, got {spec.ratios}")
    fam = rng.choice(3, size=n_txns, p=ratios / ratios.sum())
    args = np.choose(fam[:, None], fam_args).astype(np.int32)
    params = {"code": jnp.asarray(fam_code[fam]), "args": jnp.asarray(args)}

    storage = np.concatenate([np.asarray(p2p_storage), ind_storage,
                              np.asarray(adm_storage)]).astype(np.int32)
    if total_locs > storage.shape[0]:      # ≤1 parity-padding tail location
        storage = np.concatenate(
            [storage, np.zeros(total_locs - storage.shape[0], np.int32)])
    vm, cfg = BC.vm_and_config(progs, n_txns, total_locs, window=window,
                               **cfg_kw)
    return vm, params, jnp.asarray(storage), cfg

"""Block-STM core: the paper's contribution as a composable JAX module.

Scheduler + MVMemory + VM (paper Algorithms 1-5) re-derived for SIMD hardware
as a bulk-synchronous wave engine — see DESIGN.md §2 for the mapping.
"""
from repro.core.engine import make_executor, run_block, run_chain
from repro.core.types import BlockResult, BlockStats, EngineConfig
from repro.core.vm import run_sequential

__all__ = ["make_executor", "run_block", "run_chain", "BlockResult",
           "BlockStats", "EngineConfig", "run_sequential"]

"""The paper's comparison baselines, implemented (paper §4.1, Fig. 3).

* :func:`run_bohm` — Bohm [21]: a deterministic database engine that is
  GIVEN perfect write-sets before execution (the paper grants it this
  artificially, as do we: the oracle pre-pass extracts true write sets).
  Each transaction executes exactly once, as soon as every lower transaction
  that writes a location it might read has executed — a dependency-level
  (fork-join) schedule over the exact last-writer graph.  No validation, no
  aborts, no speculation: the lower bound on useful work.

* :func:`run_litm` — LiTM [52]-style deterministic STM: every round executes
  ALL pending transactions from the current committed state, then commits the
  order-greedy independent set (a txn commits iff no lower *pending* txn
  touches its read/write footprint); the rest re-execute next round.  Thrives
  at low conflict, degrades at high conflict — the behavior the paper
  contrasts against.

Both produce the preset-order-equivalent final state (tested), so all four
engines (sequential / Block-STM / Bohm / LiTM) are comparable on identical
blocks.  Execution dispatches through the shared executor protocol
(:mod:`repro.core.executor`), so the baselines run Python-DSL blocks AND
heterogeneous bytecode/mixed blocks from the same code path as the wave
engine — the paper's comparison grid extends to ``make_mixed_block``
workloads unchanged (see ``tests/test_conformance.py`` and
``benchmarks/engine_bench.py --workload baselines``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import executor, mv
from repro.core.types import NO_LOC, EngineConfig
from repro.core.vm import TxnProgram


class BaselineResult(NamedTuple):
    snapshot: jax.Array
    rounds: jax.Array
    execs: jax.Array
    committed: jax.Array


def _exec_all(program, params, storage, cfg, write_locs, write_vals,
              executed, incarnation):
    """Execute every txn against the current partial state (vmapped).

    Reads resolve against committed/executed lower txns only (like MVMemory
    restricted to final values); dispatch is the shared executor protocol,
    so DSL and bytecode programs both run here."""
    resolver = executor.committed_resolver(write_locs, executed, incarnation,
                                           cfg)
    return executor.execute_txns(program, params, storage, cfg, resolver,
                                 write_vals)


def _snapshot(write_locs, write_vals, executed, incarnation, storage, cfg):
    resolver = executor.committed_resolver(write_locs, executed, incarnation,
                                           cfg)
    return executor.read_snapshot(resolver, write_vals, storage, cfg)


def run_bohm(program: TxnProgram, params: Any, storage: jax.Array,
             cfg: EngineConfig, perfect_write_locs: jax.Array
             ) -> BaselineResult:
    """Bohm with perfect write sets. ``perfect_write_locs``: (n, W) int32
    true write locations (from the sequential oracle pre-pass)."""
    n = cfg.n_txns
    # The perfect-write-set index is static across rounds: build it once and
    # let the while-loop close over it (MV backend per cfg, like the engine).
    backend = mv.make_backend(cfg)
    perfect_index = backend.build(perfect_write_locs)
    no_estimates = jnp.zeros((n,), jnp.bool_)

    def cond(state):
        _, _, executed, _, rounds, _ = state
        return (~executed.all()) & (rounds < n + 2)

    def body(state):
        write_locs, write_vals, executed, incarnation, rounds, execs = state
        # a txn is ready when every lower writer of any location it could
        # read has executed; with perfect write sets, "could read" is bounded
        # by the true conflict graph: we conservatively require all lower
        # txns whose write set intersects this txn's (true) footprint.
        res = _exec_all(program, params, storage, cfg, write_locs, write_vals,
                        executed, incarnation)
        # ready: all lower writers of every location actually read have run
        read_locs = res.read_locs                              # (n, R)

        perfect_resolver = backend.make_resolver(
            perfect_index, perfect_write_locs, no_estimates, incarnation)

        def last_perfect_writer(loc, reader):
            return perfect_resolver(loc, reader).writer

        writers = jax.vmap(jax.vmap(last_perfect_writer))(
            read_locs, jnp.broadcast_to(
                jnp.arange(n, dtype=jnp.int32)[:, None], read_locs.shape))
        dep_ok = (writers < 0) | executed[jnp.clip(writers, 0, n - 1)]
        # res.blocked marks malformed executions (e.g. bytecode slot
        # overflow): never treat them as ready, so the round cap trips and
        # committed=False, matching the wave engine's fail-loudly semantics.
        ready = dep_ok.all(axis=1) & ~executed & ~res.blocked
        sel = lambda m, a, b: jnp.where(m[:, None] if a.ndim == 2 else m,
                                        a, b)
        return (sel(ready, res.write_locs, write_locs),
                sel(ready, res.write_vals, write_vals),
                executed | ready,
                incarnation + ready.astype(jnp.int32),
                rounds + 1,
                execs + ready.sum(dtype=jnp.int32))

    init = (jnp.full((n, cfg.max_writes), NO_LOC, jnp.int32),
            jnp.zeros((n, cfg.max_writes), cfg.value_dtype),
            jnp.zeros((n,), jnp.bool_),
            jnp.zeros((n,), jnp.int32),
            jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32))
    write_locs, write_vals, executed, incarnation, rounds, execs = \
        jax.lax.while_loop(cond, body, init)
    snapshot = _snapshot(write_locs, write_vals, executed, incarnation,
                         storage, cfg)
    return BaselineResult(snapshot=snapshot, rounds=rounds, execs=execs,
                          committed=executed.all())


def run_litm(program: TxnProgram, params: Any, storage: jax.Array,
             cfg: EngineConfig) -> BaselineResult:
    """LiTM-style rounds: execute all pending, commit the order-greedy
    conflict-free set, repeat."""
    n = cfg.n_txns

    def cond(state):
        _, _, executed, _, rounds, _ = state
        return (~executed.all()) & (rounds < n + 2)

    def body(state):
        write_locs, write_vals, executed, incarnation, rounds, execs = state
        res = _exec_all(program, params, storage, cfg, write_locs, write_vals,
                        executed, incarnation)
        pending = ~executed
        # conflict: does any lower PENDING txn write a location in my
        # read+write footprint?  (last-pending-writer lookup through the
        # cfg-selected MV backend)
        backend = mv.make_backend(cfg)
        pend_writes = jnp.where(pending[:, None], res.write_locs, NO_LOC)
        pend_resolver = backend.make_resolver(
            backend.build(pend_writes), pend_writes,
            jnp.zeros((n,), jnp.bool_), incarnation)

        def lower_writer(loc, reader):
            return pend_resolver(loc, reader).found

        foot = jnp.concatenate([res.read_locs, res.write_locs], axis=1)
        readers = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None],
                                   foot.shape)
        conflicted = jax.vmap(jax.vmap(lower_writer))(foot, readers)
        commit = pending & ~conflicted.any(axis=1) & ~res.blocked
        sel = lambda m, a, b: jnp.where(m[:, None] if a.ndim == 2 else m,
                                        a, b)
        return (sel(commit, res.write_locs, write_locs),
                sel(commit, res.write_vals, write_vals),
                executed | commit,
                incarnation + commit.astype(jnp.int32),
                rounds + 1,
                execs + pending.sum(dtype=jnp.int32))

    init = (jnp.full((n, cfg.max_writes), NO_LOC, jnp.int32),
            jnp.zeros((n, cfg.max_writes), cfg.value_dtype),
            jnp.zeros((n,), jnp.bool_),
            jnp.zeros((n,), jnp.int32),
            jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32))
    write_locs, write_vals, executed, incarnation, rounds, execs = \
        jax.lax.while_loop(cond, body, init)
    snapshot = _snapshot(write_locs, write_vals, executed, incarnation,
                         storage, cfg)
    return BaselineResult(snapshot=snapshot, rounds=rounds, execs=execs,
                          committed=executed.all())


def make_baseline_executor(kind: str, program: TxnProgram,
                           cfg: EngineConfig) -> Callable:
    """Jitted baseline executor, mirroring ``engine.make_executor``.

    ``bohm``: ``(params, storage, perfect_write_locs) -> BaselineResult``;
    ``litm``: ``(params, storage) -> BaselineResult``.  Like the wave
    engine's executor, ONE compilation serves every block with the same
    static config — including every contract mix of a bytecode block
    (property-tested via the jit cache in ``tests/test_conformance.py``).
    """
    if kind == "bohm":
        @functools.partial(jax.jit, donate_argnums=())
        def run(params, storage, perfect_write_locs):
            return run_bohm(program, params, storage, cfg, perfect_write_locs)
    elif kind == "litm":
        @functools.partial(jax.jit, donate_argnums=())
        def run(params, storage):
            return run_litm(program, params, storage, cfg)
    else:
        raise ValueError(f"unknown baseline kind {kind!r}")
    return run


def perfect_write_sets(program: TxnProgram, params: Any, storage,
                       cfg: EngineConfig) -> jax.Array:
    """Oracle pre-pass: true write locations per txn (what the paper grants
    Bohm 'artificially').  Runs the program's sequential (``__call__``)
    representation, so DSL and bytecode programs both work."""
    import numpy as np
    from repro.core.vm import OracleCtx, unstack_params
    plist = unstack_params(params, cfg.n_txns)
    state: dict = {}
    out = np.full((cfg.n_txns, cfg.max_writes), NO_LOC, np.int32)
    for j, p in enumerate(plist):
        ctx = OracleCtx(state, np.asarray(storage))
        program(p, ctx)
        for k, loc in enumerate(list(ctx._buffer.keys())[:cfg.max_writes]):
            out[j, k] = loc
        ctx.commit()
    return jnp.asarray(out)

"""Core types for the Block-STM wave engine.

The engine state mirrors the paper's modules:
  * MVMemory   -> per-transaction write-slot arrays + per-txn ESTIMATE flag
                  (paper Algorithm 2: ``data``, ``last_written_locations``,
                  ``last_read_set``).
  * Scheduler  -> ``needs_exec`` / ``executed`` / ``blocked_by`` masks +
                  ``incarnation`` counters + the commit ``frontier``
                  (paper Algorithm 4/5 status array; the two atomic counters
                  become the wave window / the full-vector validation pass).

Everything is a flat JAX array so the whole engine state threads through a
single ``lax.while_loop`` carry and can be donated.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

NO_LOC = -1            # unused read/write slot
STORAGE = -1           # read resolved from pre-block storage (paper: version ⊥)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static configuration of a block execution."""

    n_txns: int                  # BLOCK.size()
    n_locs: int                  # size of the location universe for this block
    max_reads: int               # R: read-slot bound per incarnation
    max_writes: int              # W: write-slot bound per incarnation
    window: int = 32             # #virtual threads (lowest-index-first width)
    validation_window: int = 0   # 0 = validate all executed txns per wave;
                                 # >0 = only [frontier, frontier+vw) — the
                                 # paper's validation_idx sweep (perf: O(vw)
                                 # instead of O(n) validation per wave)
    max_waves: int = 0           # 0 -> auto (2*n + 8)
    value_dtype: jnp.dtype = jnp.int32
    backend: str = "sorted"      # 'sorted' | 'dense' (dense uses the Pallas kernel path)
    use_pallas: bool = False     # dense backend: pallas mv_resolve (interpret on CPU)
    track_write_stability: bool = True  # paper's wrote_new_location statistic

    def __post_init__(self):
        # sorted-index keys are loc*(n+1)+writer in int32 (x64 is disabled).
        if self.n_locs * (self.n_txns + 1) + self.n_txns >= 2**31:
            raise ValueError(
                f"n_locs*(n_txns+1) overflows int32 index keys "
                f"({self.n_locs}*{self.n_txns + 1}); shrink the block or "
                f"location universe, or shard the block.")

    def waves_cap(self) -> int:
        return self.max_waves if self.max_waves > 0 else 2 * self.n_txns + 8


class EngineState(NamedTuple):
    """Carry of the wave loop. Shapes: n = n_txns, W = max_writes, R = max_reads."""

    # -- MVMemory ----------------------------------------------------------
    write_locs: jax.Array        # (n, W) i32, NO_LOC = empty slot
    write_vals: jax.Array        # (n, W) value_dtype
    estimate: jax.Array          # (n,)  bool: last write-set is ESTIMATE-marked
    # -- recorded read sets (paper: last_read_set) ---------------------------
    read_locs: jax.Array         # (n, R) i32, NO_LOC = empty slot
    read_writer: jax.Array       # (n, R) i32, STORAGE = from storage
    read_inc: jax.Array          # (n, R) i32 incarnation of writer at read time
    # -- Scheduler ----------------------------------------------------------
    incarnation: jax.Array       # (n,) i32: number of finished executions
    executed: jax.Array          # (n,) bool: has a live (non-aborted) result
    needs_exec: jax.Array        # (n,) bool: scheduled for (re-)execution
    blocked_by: jax.Array        # (n,) i32: txn idx whose ESTIMATE blocked us, or -1
    frontier: jax.Array          # () i32: txns < frontier are committed
    wave: jax.Array              # () i32
    # -- sorted multi-version index (rebuilt each wave) ----------------------
    idx_keys: jax.Array          # (n*W,) i32 sorted keys loc*(n+1)+writer, dead=MAX
                                 # (int32 by construction: x64 is disabled and
                                 # EngineConfig.__post_init__ rejects overflow)
    idx_txn: jax.Array           # (n*W,) i32 writer txn of the sorted entry
    idx_slot: jax.Array          # (n*W,) i32 write slot of the sorted entry
    # -- statistics ----------------------------------------------------------
    stat_execs: jax.Array        # () i32 total incarnations executed
    stat_dep_aborts: jax.Array   # () i32 executions aborted on an ESTIMATE read
    stat_val_aborts: jax.Array   # () i32 validation failures that aborted
    stat_wrote_new: jax.Array    # () i32 incarnations that wrote a new location


class ExecResult(NamedTuple):
    """Output of one VM incarnation (vmapped across the wave)."""

    read_locs: jax.Array         # (R,) i32
    read_writer: jax.Array       # (R,) i32
    read_inc: jax.Array          # (R,) i32
    write_locs: jax.Array        # (W,) i32
    write_vals: jax.Array        # (W,) value_dtype
    blocked: jax.Array           # () bool: hit a lower-txn ESTIMATE (READ_ERROR)
    blocker: jax.Array           # () i32: blocking txn idx


class BlockResult(NamedTuple):
    """Result of executing one block."""

    snapshot: jax.Array          # (n_locs,) final state (MVMemory.snapshot over storage)
    committed: jax.Array         # () bool: frontier == n (False => wave cap hit)
    waves: jax.Array             # () i32
    execs: jax.Array             # () i32 total incarnations
    dep_aborts: jax.Array       # () i32
    val_aborts: jax.Array       # () i32
    wrote_new: jax.Array        # () i32

"""Core types for the Block-STM wave engine.

The engine state mirrors the paper's modules:
  * MVMemory   -> per-transaction write-slot arrays + per-txn ESTIMATE flag
                  (paper Algorithm 2: ``data``, ``last_written_locations``,
                  ``last_read_set``).
  * Scheduler  -> ``needs_exec`` / ``executed`` / ``blocked_by`` masks +
                  ``incarnation`` counters + the commit ``frontier``
                  (paper Algorithm 4/5 status array; the two atomic counters
                  become the wave window / the full-vector validation pass).

Everything is a flat JAX array so the whole engine state threads through a
single ``lax.while_loop`` carry and can be donated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

NO_LOC = -1            # unused read/write slot
STORAGE = -1           # read resolved from pre-block storage (paper: version ⊥)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static configuration of a block execution."""

    n_txns: int                  # BLOCK.size()
    n_locs: int                  # size of the location universe for this block
    max_reads: int               # R: read-slot bound per incarnation
    max_writes: int              # W: write-slot bound per incarnation
    window: int = 32             # #virtual threads (lowest-index-first width)
    validation_window: int = 0   # 0 = validate all executed txns per wave;
                                 # >0 = only [frontier, frontier+vw) — the
                                 # paper's validation_idx sweep (perf: O(vw)
                                 # instead of O(n) validation per wave)
    max_waves: int = 0           # 0 -> auto (2*n + 8)
    value_dtype: jnp.dtype = jnp.int32
    backend: str = "sorted"      # 'sorted' | 'dense' | 'sharded' (repro.core.mv)
    use_pallas: bool = False     # dense backend: pallas mv_resolve (interpret on CPU)
    n_shards: int = 0            # sharded backend: region count (0 = fewest
                                 # shards keeping shard-local keys in int32)
    mv_update: str = "incremental"   # per-wave index maintenance:
                                 # 'incremental' = backend.update (delta merge,
                                 # O(wave) sort work) | 'rebuild' = backend.build
                                 # (O(block); the reference semantics)
    dirty_validation: bool = True    # skip re-validating rows whose every read
                                 # region is version-clean since their last
                                 # validation (needs mv_update='incremental'
                                 # and full validation, i.e. validation_window
                                 # == 0; silently inert otherwise)
    dirty_validation_cap: int = 0    # max rows validated per wave on the skip
                                 # path before falling back to a full pass
                                 # (0 = auto: min(n_txns, max(2*window, 64)))
    resolver_impl: str = "xla"   # sharded backend read resolution: 'xla'
                                 # (segment_searchsorted) | 'pallas'
                                 # (kernels/mv_region_resolve; interpret off-TPU)
    dist: bool = False           # multi-device execution (repro.core.dist):
                                 # run the block under jax.shard_map with each
                                 # MV region's index segment, version counter,
                                 # and snapshot slice placed on a fixed device
                                 # of a 1-D 'regions' mesh.  Requires
                                 # backend='sharded' (the CSR region seam).
    mesh: Any = None             # dist=True: explicit 1-D jax.sharding.Mesh
                                 # with axis ('regions',); None = lazily build
                                 # one over ALL available devices at trace
                                 # time (launch.mesh.make_mesh)
    track_write_stability: bool = True  # paper's wrote_new_location statistic
    trace_level: int = 0         # in-jit wave telemetry (repro.obs.trace):
                                 # 0 = off (compiles to the exact untraced
                                 # program — the record hooks are never
                                 # traced); 1 = per-wave scalar counters in
                                 # (waves_cap,) ring buffers; 2 = level 1 +
                                 # (waves_cap, window) dep-abort attribution
                                 # edges.  The WaveTrace rides EngineState
                                 # .trace and returns in BlockResult.trace.
    guard_level: int = 0         # in-jit invariant checks (repro.guard):
                                 # 0 = off (compiles to the exact unguarded
                                 # program); 1 = per-wave O(n) structural
                                 # checks; 2 = level 1 + the adversarial
                                 # checks (read-universe bounds, dirty-skip
                                 # shadow validation).  The GuardReport
                                 # rides EngineState.guard and returns in
                                 # BlockResult.guard.
    chaos: Any = None            # repro.guard.chaos.ChaosConfig | None:
                                 # deterministic PRNG-keyed schedule
                                 # perturbation inside the wave loop.  None
                                 # (default) is static like trace_level=0 —
                                 # the chaos hooks are never traced.
    degrade_on_stall: bool = True  # waves_cap exhausted without frontier ==
                                 # n_txns -> lax.cond into the deterministic
                                 # sequential executor (repro.guard.degrade)
                                 # so the block still commits its preset-
                                 # order state (BlockResult.degraded=True).
                                 # False restores the bare committed=False
                                 # partial-snapshot exit.

    def __post_init__(self):
        # Shape sanity first: a nonsense extent would otherwise surface much
        # later as an opaque XLA shape error (or a silent zero-progress
        # while_loop running to waves_cap).
        if self.n_txns <= 0:
            raise ValueError(f"n_txns={self.n_txns}: a block must contain at "
                             f"least one transaction")
        if self.n_locs <= 0:
            raise ValueError(f"n_locs={self.n_locs}: the location universe "
                             f"must be non-empty")
        if self.max_reads <= 0 or self.max_writes <= 0:
            raise ValueError(
                f"max_reads={self.max_reads}, max_writes={self.max_writes}: "
                f"the per-incarnation read/write slot bounds must be "
                f"positive (a zero-slot VM cannot record any access)")
        if self.window <= 0:
            raise ValueError(f"window={self.window}: the wave needs at least "
                             f"one lane (virtual thread)")
        if self.validation_window < 0:
            raise ValueError(
                f"validation_window={self.validation_window}: expected 0 "
                f"(validate all executed txns per wave) or a positive sweep "
                f"width")
        if self.max_waves < 0:
            raise ValueError(
                f"max_waves={self.max_waves}: expected 0 (auto cap: "
                f"2*n_txns + 8) or a positive wave budget — a negative "
                f"value would silently alias the auto cap")
        if self.backend not in ("sorted", "dense", "sharded"):
            raise ValueError(f"unknown MV backend {self.backend!r}; expected "
                             f"'sorted', 'dense', or 'sharded'")
        if self.mv_update not in ("incremental", "rebuild"):
            raise ValueError(f"unknown mv_update {self.mv_update!r}; expected "
                             f"'incremental' or 'rebuild'")
        if self.resolver_impl not in ("xla", "pallas"):
            raise ValueError(f"unknown resolver_impl {self.resolver_impl!r}; "
                             f"expected 'xla' or 'pallas'")
        if self.resolver_impl == "pallas" and self.backend != "sharded":
            raise ValueError(
                f"resolver_impl='pallas' is the sharded backend's region-"
                f"resolve kernel; backend={self.backend!r} does not use it "
                f"(the dense backend's kernel switch is use_pallas)")
        if self.dist and self.backend != "sharded":
            raise ValueError(
                f"dist=True shard_maps the sharded backend's per-region "
                f"index segments across devices; backend={self.backend!r} "
                f"has no region partition to place (use backend='sharded')")
        if self.mesh is not None and not self.dist:
            raise ValueError("mesh is only meaningful with dist=True")
        if self.trace_level not in (0, 1, 2):
            raise ValueError(
                f"trace_level={self.trace_level!r}: expected 0 (off), 1 "
                f"(per-wave counters), or 2 (counters + abort-attribution "
                f"edges) — see repro.obs.trace")
        if self.guard_level not in (0, 1, 2):
            raise ValueError(
                f"guard_level={self.guard_level!r}: expected 0 (off), 1 "
                f"(structural per-wave checks), or 2 (+ adversarial "
                f"checks) — see repro.guard.invariants")
        if self.chaos is not None:
            from repro.guard.chaos import ChaosConfig
            if not isinstance(self.chaos, ChaosConfig):
                raise ValueError(
                    f"chaos={self.chaos!r}: expected a "
                    f"repro.guard.chaos.ChaosConfig (or None for the "
                    f"unperturbed engine)")
        if self.mesh is not None and tuple(self.mesh.axis_names) != \
                ("regions",):
            raise ValueError(
                f"dist mesh must be 1-D with axis ('regions',), got axes "
                f"{tuple(self.mesh.axis_names)} (see launch.mesh.make_mesh)")
        # Index keys are loc*(n+1)+writer in int32 (x64 is disabled).  The
        # flat backends key the whole universe; 'sharded' keys per region, so
        # only the region size is bounded (shard_plan validates it and raises
        # its own ValueError for an explicit n_shards that is too small).
        if self.backend == "sharded":
            from repro.core.mv.sharded import shard_plan
            shard_plan(self.n_locs, self.n_txns, self.n_shards)
        elif self.n_locs * (self.n_txns + 1) + self.n_txns >= 2**31:
            raise ValueError(
                f"MV index keys loc*(n_txns+1)+writer overflow int32 for "
                f"n_locs={self.n_locs}, n_txns={self.n_txns} under "
                f"backend={self.backend!r}; use backend='sharded' (shard-"
                f"local keys survive any universe size), or shrink the "
                f"block or location universe.")

    def waves_cap(self) -> int:
        return self.max_waves if self.max_waves > 0 else 2 * self.n_txns + 8

    def dirty_cap(self) -> int:
        """Row capacity of the dirty-validation gather path (resolved)."""
        if self.dirty_validation_cap > 0:
            return min(self.n_txns, self.dirty_validation_cap)
        return min(self.n_txns, max(2 * self.window, 64))


class EngineState(NamedTuple):
    """Carry of the wave loop. Shapes: n = n_txns, W = max_writes, R = max_reads."""

    # -- MVMemory ----------------------------------------------------------
    write_locs: jax.Array        # (n, W) i32, NO_LOC = empty slot
    write_vals: jax.Array        # (n, W) value_dtype
    estimate: jax.Array          # (n,)  bool: last write-set is ESTIMATE-marked
    # -- recorded read sets (paper: last_read_set) ---------------------------
    read_locs: jax.Array         # (n, R) i32, NO_LOC = empty slot
    read_writer: jax.Array       # (n, R) i32, STORAGE = from storage
    read_inc: jax.Array          # (n, R) i32 incarnation of writer at read time
    read_region_ver: jax.Array   # (n, R) i32 version of the read loc's MV
                                 # region when the row was last resolved /
                                 # validated (dirty-region validation skip)
    # -- Scheduler ----------------------------------------------------------
    incarnation: jax.Array       # (n,) i32: number of finished executions
    executed: jax.Array          # (n,) bool: has a live (non-aborted) result
    needs_exec: jax.Array        # (n,) bool: scheduled for (re-)execution
    blocked_by: jax.Array        # (n,) i32: txn idx whose ESTIMATE blocked us, or -1
    frontier: jax.Array          # () i32: txns < frontier are committed
    wave: jax.Array              # () i32
    # -- multi-version index (rebuilt each wave) -----------------------------
    index: Any                   # backend-owned pytree of arrays (fixed shape
                                 # per EngineConfig): SortedIndex /
                                 # DenseIndex / ShardedIndex — see
                                 # repro.core.mv (MVBackend protocol)
    # -- statistics ----------------------------------------------------------
    stat_execs: jax.Array        # () i32 total incarnations executed
    stat_dep_aborts: jax.Array   # () i32 executions aborted on an ESTIMATE read
    stat_val_aborts: jax.Array   # () i32 validation failures that aborted
    stat_wrote_new: jax.Array    # () i32 incarnations that wrote a new location
    # -- telemetry -----------------------------------------------------------
    trace: Any = None            # repro.obs.trace.WaveTrace per-wave ring
                                 # buffers (trace_level >= 1), or None —
                                 # an EMPTY pytree node, so level 0 carries
                                 # exactly the pre-telemetry state
    guard: Any = None            # repro.guard.invariants.GuardReport
                                 # (guard_level >= 1), or None — likewise an
                                 # empty pytree node at level 0

    @classmethod
    def dist_spec(cls) -> "EngineState":
        """Partitioning of the state at a ``shard_map`` boundary of the
        multi-device engine (:mod:`repro.core.dist`): scheduler/MVMemory
        arrays replicated (they are int32-deterministic on every device),
        the backend-owned region index device-concatenated along its leading
        axis (``PartitionSpec('regions')`` — ShardedIndex leaves are 1-D, so
        this concatenates the per-device keys/packed/starts/version lists).
        """
        from jax.sharding import PartitionSpec as P
        return cls(
            write_locs=P(), write_vals=P(), estimate=P(), read_locs=P(),
            read_writer=P(), read_inc=P(), read_region_ver=P(),
            incarnation=P(), executed=P(), needs_exec=P(), blocked_by=P(),
            frontier=P(), wave=P(), index=P("regions"), stat_execs=P(),
            stat_dep_aborts=P(), stat_val_aborts=P(), stat_wrote_new=P(),
            # Trace buffers cross phase boundaries as-if-replicated (prefix
            # spec over the WaveTrace pytree, or the empty None node at
            # level 0).  The per-device fields (mv_entries/dirty_regions)
            # are only truly local INSIDE a block; the production dist path
            # all_gathers them before the state ever crosses this spec
            # (repro.obs.trace.merge_device_traces).
            trace=P(),
            # Guard reports are replicated except the device-local index
            # check; the dist engine merges them on block exit
            # (repro.guard.invariants.merge_device_reports).
            guard=P())


class ExecResult(NamedTuple):
    """Output of one VM incarnation (vmapped across the wave)."""

    read_locs: jax.Array         # (R,) i32
    read_writer: jax.Array       # (R,) i32
    read_inc: jax.Array          # (R,) i32
    write_locs: jax.Array        # (W,) i32
    write_vals: jax.Array        # (W,) value_dtype
    blocked: jax.Array           # () bool: hit a lower-txn ESTIMATE (READ_ERROR)
    blocker: jax.Array           # () i32: blocking txn idx


class BlockStats(NamedTuple):
    """Per-block execution counters WITHOUT the snapshot.

    This is the carry-friendly result type: ``run_chain`` scans over blocks
    and stacks one :class:`BlockStats` per block, instead of smuggling a
    placeholder array through :class:`BlockResult`'s snapshot field.
    """

    committed: jax.Array         # () bool: the snapshot is the preset-order
                                 # state (wave loop converged, or the
                                 # degradation fallback committed it)
    degraded: jax.Array          # () bool: the sequential fallback produced
                                 # the committed state (wave cap exhausted)
    waves: jax.Array             # () i32
    execs: jax.Array             # () i32 total incarnations
    dep_aborts: jax.Array       # () i32
    val_aborts: jax.Array       # () i32
    wrote_new: jax.Array        # () i32


class BlockResult(NamedTuple):
    """Result of executing one block."""

    snapshot: jax.Array          # (n_locs,) final state (MVMemory.snapshot over storage)
    committed: jax.Array         # () bool: snapshot is the preset-order
                                 # state (False only when degradation is off
                                 # or the block is unsound even sequentially)
    degraded: jax.Array          # () bool: committed via the sequential
                                 # fallback (repro.guard.degrade) after the
                                 # wave cap ran out
    waves: jax.Array             # () i32
    execs: jax.Array             # () i32 total incarnations
    dep_aborts: jax.Array       # () i32
    val_aborts: jax.Array       # () i32
    wrote_new: jax.Array        # () i32
    trace: Any = None           # WaveTrace ring buffers (trace_level >= 1);
                                # rows past `waves` are unwritten — trim
                                # host-side (repro.obs.export.trace_to_dict)
    guard: Any = None           # GuardReport (guard_level >= 1) — see
                                # repro.guard.invariants.summarize

    def stats(self) -> BlockStats:
        """The snapshot-free view (typed; see :class:`BlockStats`)."""
        return BlockStats(committed=self.committed, degraded=self.degraded,
                          waves=self.waves, execs=self.execs,
                          dep_aborts=self.dep_aborts,
                          val_aborts=self.val_aborts,
                          wrote_new=self.wrote_new)

"""Transaction VM (paper Algorithm 3).

A *transaction program* is a Python function

    def txn(params, ctx) -> None

that performs a bounded number of ``ctx.read(loc)`` / ``ctx.write(loc, value,
enabled=...)`` calls.  Read addresses may depend on previously read values
(dynamic read sets); writes may be conditionally enabled (dynamic write sets) —
the two properties that distinguish Block-STM's setting from Bohm/Calvin, which
assume write sets are known up front.

The same program runs in two harnesses:

* ``SpecCtx``     — speculative JAX execution inside the wave engine (vmapped).
                    Reads resolve against MVMemory; ESTIMATE hits set the
                    ``blocked`` flag (paper: READ_ERROR -> add_dependency).
* ``OracleCtx``   — plain-Python sequential execution (the reference the paper
                    itself validates against).

Because the *number of textual read()/write() call sites is static*, slot
indices are Python ints: the recorded read/write sets are fixed-shape arrays
with NO_LOC padding, which is what makes the whole engine vmappable.

Executor protocol: every engine (wave, Bohm, LiTM) executes transactions
through :func:`make_exec_one`, which dispatches on the program representation:
objects exposing ``execute_spec(cfg, txn_idx, resolver, value_reader, p) ->
ExecResult`` (e.g. :class:`repro.bytecode.interp.BytecodeVM`) manage their own
slot accounting — programs are per-txn *data* — while plain callables
``(params, ctx) -> None`` run under :class:`SpecCtx` with static slot call
sites.  Block-level helpers live in :mod:`repro.core.executor`.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.types import NO_LOC, STORAGE, EngineConfig, ExecResult

TxnProgram = Callable[..., None]  # (params, ctx) -> None


def make_exec_one(program: "TxnProgram", cfg: EngineConfig, resolver,
                  value_reader) -> Callable:
    """The executor protocol's single dispatch point.

    Returns ``exec_one(txn_idx, p) -> ExecResult`` executing ONE speculative
    incarnation against the multi-version view exposed by ``resolver`` /
    ``value_reader``.  Both program representations are served:

    * objects with ``execute_spec`` (bytecode VMs: programs are per-txn data),
    * plain Python-DSL callables, traced under :class:`SpecCtx`.

    Every engine — the Block-STM wave loop, Bohm, LiTM — builds its per-wave
    executors through this function, so heterogeneous blocks run everywhere
    the moment a program representation implements the protocol.
    """
    execute_spec = getattr(program, "execute_spec", None)
    if execute_spec is not None:
        def exec_one(txn_idx, p):
            return execute_spec(cfg, txn_idx, resolver, value_reader, p)
    else:
        def exec_one(txn_idx, p):
            ctx = SpecCtx(cfg, txn_idx, resolver, value_reader)
            program(p, ctx)
            return ctx.result()
    return exec_one


class SpecCtx:
    """Speculative execution context: reads via MVMemory, writes buffered.

    Mirrors Algorithm 3: reads check the own write-set first (L84), then
    MVMemory (L87), then storage (L90); every MV/storage read is recorded with
    its version for later validation.  An ESTIMATE resolution marks the
    execution blocked (L95-96) — the engine discards buffered effects and
    registers the dependency.
    """

    def __init__(self, cfg: EngineConfig, txn_idx: jax.Array, resolver,
                 value_reader):
        self.cfg = cfg
        self.txn_idx = txn_idx
        self._resolver = resolver          # (loc, reader) -> ReadResolution
        self._value_reader = value_reader  # (resolution, loc) -> value
        self.read_locs = jnp.full((cfg.max_reads,), NO_LOC, jnp.int32)
        self.read_writer = jnp.full((cfg.max_reads,), STORAGE, jnp.int32)
        self.read_inc = jnp.full((cfg.max_reads,), -1, jnp.int32)
        self.write_locs = jnp.full((cfg.max_writes,), NO_LOC, jnp.int32)
        self.write_vals = jnp.zeros((cfg.max_writes,), cfg.value_dtype)
        self.blocked = jnp.asarray(False)
        self.blocker = jnp.asarray(-1, jnp.int32)
        self._r = 0  # static slot counters
        self._w = 0

    # -- paper L83-96 --------------------------------------------------------
    def read(self, loc, *, enabled=True) -> jax.Array:
        if self._r >= self.cfg.max_reads:
            raise ValueError(f"transaction exceeds max_reads={self.cfg.max_reads}")
        loc = jnp.asarray(loc, jnp.int32)
        enabled = jnp.asarray(enabled) & ~self.blocked
        eff_loc = jnp.where(enabled, loc, NO_LOC)
        # read-own-write (L84): newest matching buffered write wins.
        own_hit = jnp.asarray(False)
        own_val = jnp.zeros((), self.cfg.value_dtype)
        for s in range(self._w):
            m = self.write_locs[s] == eff_loc
            own_hit = own_hit | m
            own_val = jnp.where(m, self.write_vals[s], own_val)
        res = self._resolver(eff_loc, self.txn_idx)
        mv_val = self._value_reader(res, eff_loc)
        value = jnp.where(own_hit, own_val, mv_val)
        # record (skip own-write hits: they are not MV reads, exactly as L84).
        rec = enabled & ~own_hit
        self.read_locs = self.read_locs.at[self._r].set(jnp.where(rec, eff_loc, NO_LOC))
        self.read_writer = self.read_writer.at[self._r].set(
            jnp.where(rec & res.found, res.writer, STORAGE))
        self.read_inc = self.read_inc.at[self._r].set(
            jnp.where(rec & res.found, res.inc, -1))
        self._r += 1
        # ESTIMATE -> READ_ERROR (L95): first blocker wins.
        hit_est = rec & res.is_estimate & ~self.blocked
        self.blocker = jnp.where(hit_est, res.writer, self.blocker)
        self.blocked = self.blocked | hit_est
        return value

    # -- paper L77-81 --------------------------------------------------------
    def write(self, loc, value, *, enabled=True) -> None:
        if self._w >= self.cfg.max_writes:
            raise ValueError(f"transaction exceeds max_writes={self.cfg.max_writes}")
        loc = jnp.asarray(loc, jnp.int32)
        enabled = jnp.asarray(enabled) & ~self.blocked
        value = jnp.asarray(value, self.cfg.value_dtype)
        # latest-value-per-location (L78-80): disable earlier slots on same loc.
        for s in range(self._w):
            dup = enabled & (self.write_locs[s] == loc)
            self.write_locs = self.write_locs.at[s].set(
                jnp.where(dup, NO_LOC, self.write_locs[s]))
        self.write_locs = self.write_locs.at[self._w].set(
            jnp.where(enabled, loc, NO_LOC))
        self.write_vals = self.write_vals.at[self._w].set(
            jnp.where(enabled, value, 0))
        self._w += 1

    def result(self) -> ExecResult:
        return ExecResult(
            read_locs=self.read_locs, read_writer=self.read_writer,
            read_inc=self.read_inc, write_locs=self.write_locs,
            write_vals=self.write_vals, blocked=self.blocked, blocker=self.blocker)


class OracleCtx:
    """Sequential reference context over a dict (the paper's correctness oracle)."""

    def __init__(self, state: dict, storage):
        self._state = state
        self._storage = storage
        self._buffer: dict = {}

    def read(self, loc, *, enabled=True):
        import numpy as np
        loc = int(np.asarray(loc)); enabled = bool(np.asarray(enabled))
        if not enabled:
            return np.int64(0)
        if loc in self._buffer:
            return self._buffer[loc]
        if loc in self._state:
            return self._state[loc]
        return self._storage[loc]

    def write(self, loc, value, *, enabled=True):
        import numpy as np
        loc = int(np.asarray(loc)); enabled = bool(np.asarray(enabled))
        if enabled:
            self._buffer[loc] = np.asarray(value)

    def commit(self):
        self._state.update(self._buffer)
        self._buffer = {}


def unstack_params(params, n_txns: int):
    """dict-of-arrays (leading dim n) -> list of per-txn numpy dicts."""
    import numpy as np
    leaves = jax.tree_util.tree_map(lambda a: np.asarray(a), params)
    flat, treedef = jax.tree_util.tree_flatten(leaves)
    return [jax.tree_util.tree_unflatten(treedef, [f[i] for f in flat])
            for i in range(n_txns)]


def run_sequential(program: TxnProgram, params, storage, n_txns=None):
    """Execute the block sequentially (tx_1, tx_2, ...): the ground truth.

    Returns the final dense state vector (storage with all committed writes
    applied), comparable to ``BlockResult.snapshot``.
    """
    import numpy as np
    if not isinstance(params, list):
        params = unstack_params(params, n_txns)
    storage = np.asarray(storage)
    state: dict = {}
    for p in params:
        ctx = OracleCtx(state, storage)
        program(p, ctx)
        ctx.commit()
    out = storage.copy()
    for loc, val in state.items():
        out[loc] = val
    return out

"""The wave engine under ``jax.shard_map``: one SPMD program per block.

:func:`run_block_dist` wraps the UNCHANGED single-device engine loop
(:func:`repro.core.engine._run_block_impl`) in one ``shard_map`` over the
1-D ``'regions'`` mesh.  Inside, ``mv.make_backend(cfg)`` resolves to the
:class:`~repro.core.dist.backend.DistShardedBackend`, so the per-device
program carries the scheduler state REPLICATED (it is pure int32 arithmetic
on identical inputs — bit-deterministic, so replication holds by
construction; ``check_rep`` is off because the engine's collectives live
inside ``lax.while_loop``/``lax.cond``, beyond the static replication
checker) and the MV index LOCAL, with the backend's hooks supplying exactly
the collectives each phase needs:

=================  =====================================================
phase              communication
=================  =====================================================
execute            lanes partitioned ``window/D`` per device; per-read
                   two-hop routed ``all_to_all`` exchange + one
                   ``ExecResult`` ``all_gather`` (+ ``(S,)`` version
                   counters under the dirty-validation skip)
index (update)     none — shard-local event merge
validate           two-hop routed ``all_to_all`` resolve + ``(S,)`` versions
snapshot           span-local reads + one value ``all_gather``
=================  =====================================================

:func:`make_phase_fns` exposes the same phases as separately-jitted
shard_mapped callables for the per-wave phase benchmark
(``benchmarks/dist_bench.py``), with the state crossing the shard_map
boundary under :meth:`repro.core.types.EngineState.dist_spec` — the index
travels as device-concatenated global arrays (``PartitionSpec('regions')``),
everything else replicated.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.dist.plan import resolve_mesh
from repro.core.types import BlockResult, EngineConfig, EngineState


def _sm(mesh, fn, in_specs, out_specs):
    """shard_map with replication checking off (see module docstring)."""
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def run_block_dist(program, params: Any, storage: jax.Array,
                   cfg: EngineConfig) -> BlockResult:
    """Execute one block with MV regions placed across the config's mesh.

    Jit-compatible; exact: byte-identical snapshot and identical statistics
    to ``run_block`` with the single-device ``sharded`` backend (property-
    tested in ``tests/test_dist.py``).  All inputs are replicated (storage is
    read-only during a block — its per-region placement is realized by the
    snapshot/update phases only ever touching the owning device's span) and
    the :class:`BlockResult` comes back replicated, so chains
    (``run_chain``) scan over it unchanged.
    """
    from repro import obs
    from repro.core import engine as E
    from repro.core.dist.plan import AXIS
    mesh = resolve_mesh(cfg)

    def body(p, s):
        res = E._run_block_impl(program, p, s, cfg)
        if cfg.trace_level:
            # Per-device telemetry (local index occupancy / locally dirtied
            # regions) folds into replicated (D, cap) buffers with ONE
            # all_gather; every other trace field is a function of the
            # replicated scheduler state and is already identical
            # everywhere.
            res = res._replace(trace=obs.merge_device_traces(res.trace,
                                                             AXIS))
        if cfg.guard_level:
            # Invariant counters are mostly replicated already; the index-
            # occupancy check is per-device (local CSR vs local write set),
            # so fold violation counts with a max / first-wave with a min.
            from repro.guard import invariants as guard_inv
            res = res._replace(guard=guard_inv.merge_device_reports(
                res.guard, AXIS))
        return res

    inner = _sm(mesh, body, in_specs=(P(), P()), out_specs=P())
    return inner(params, storage)


def make_phase_fns(program, params: Any, storage: jax.Array,
                   cfg: EngineConfig) -> dict[str, Callable]:
    """The engine's phase functions as separately-jitted shard_map programs.

    Benchmark-only (``benchmarks/dist_bench.py`` replays the wave loop in
    Python to time each phase per wave, mirroring ``hotpath_bench``); the
    production path is the single-shard_map :func:`run_block_dist`.  The
    returned callables close over ``params``/``storage`` (replicated
    captures) and exchange :class:`EngineState` via :data:`STATE_SPEC`.
    """
    from repro.core import engine as E
    mesh = resolve_mesh(cfg)
    jit = jax.jit
    ss = EngineState.dist_spec()

    init = jit(_sm(mesh, lambda _: E._init_state(cfg),
                   in_specs=(P(),), out_specs=ss))
    execute = jit(_sm(
        mesh, lambda s: E._execute_phase(s, program, params, storage, cfg),
        in_specs=(ss,), out_specs=(ss, P())))
    index_phase = jit(_sm(mesh, lambda s, d: E._index_phase(s, d, cfg),
                          in_specs=(ss, P()), out_specs=ss))
    validate = jit(_sm(
        mesh, lambda s: E._validate_all(s, cfg)._replace(wave=s.wave + 1),
        in_specs=(ss,), out_specs=ss))
    snapshot = jit(_sm(mesh, lambda s: E._snapshot(s, storage, cfg),
                       in_specs=(ss,), out_specs=P()))
    return dict(init=functools.partial(init, storage), execute=execute,
                index=index_phase, validate=validate, snapshot=snapshot)

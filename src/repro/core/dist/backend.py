"""DistShardedBackend: the sharded MV index, placed across a device mesh.

One :class:`~repro.core.mv.base.MVBackend` whose methods run INSIDE the
``shard_map`` over the 1-D ``'regions'`` mesh (:mod:`repro.core.dist.plan`).
Each device holds a *local* :class:`~repro.core.mv.sharded.ShardedIndex`
covering only its own contiguous run of regions — same CSR layout, same
shard-local keys, per-device capacity ``n*W`` — produced by delegating to a
per-device single-device :class:`~repro.core.mv.sharded.ShardedBackend` over
localized write locations (``loc - device_base``; foreign locations masked to
``NO_LOC``).  Because shard-local keys are region-relative, every local
segment is byte-identical to the corresponding segment of the single-device
index, which is what makes the whole dist engine exact.

Communication per hook (and nothing else crosses devices):

* ``build``/``update``   — none.  Each device event-merges only the write
  events that land in its regions; the per-region ``version`` counters live
  with their regions (local ``(regions_per_device,)`` slice).
* ``execute_routed``     — the wave's lanes are partitioned across the mesh
  (``ceil(window / D)`` lanes per device; fill lanes pad the tail) and each
  device executes only its slice.  Execution reads are discovered
  mid-transaction (pointer indirection) and cannot be pre-routed, so each
  read surfaces as a per-step routed exchange: a ``custom_vmap`` resolver
  whose batch rule runs the same two-hop ``all_to_all`` routing as
  ``resolve_batch`` over the device's lane batch.  One ``ExecResult``
  ``all_gather`` re-replicates the wave.
* ``make_resolver``      — ``all_gather`` of keys/packed/starts into a full
  index view (the replicated-execution reference path; kept as the routed
  paths' equivalence oracle, no longer on the engine's wave loop).
* ``resolve_batch``      — the two-hop routed query: the flat query batch is
  chunked across devices, each device buckets its chunk by the owning device
  (``region_of(loc) // regions_per_device``), ``all_to_all``s the buckets,
  answers foreign queries against its own segments with the ordinary segment
  search, ``all_to_all``s the answers back, and ``all_gather``s the chunks.
* ``snapshot``           — no routing at all: device ``d``'s snapshot slice
  reads exactly its own location span locally; one value ``all_gather``.
* ``version_view``       — ``all_gather`` of the ``(regions_per_device,)``
  counters (the cheap ``(S,)``-only collective the dirty-validation skip
  consumes); ``bump_versions`` applies each device's own slice of the
  engine's global dirty mask.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.dist.plan import AXIS, plan_for, resolve_mesh
from repro.core.mv.base import (BackendDefaults, ReadResolution,
                                dirty_from_delta, finalize_resolution,
                                resolve_value)
from repro.core.mv.sharded import ShardedBackend, ShardedIndex, select_search
from repro.core.types import NO_LOC


def _routed_read_fn(backend: "DistShardedBackend", w: int):
    """Per-read routed resolver core: a ``custom_vmap`` over (loc, reader).

    Execution reads surface one scalar call per lane inside the transaction
    VM (a static DSL call site, or one ``lax.scan`` step of the bytecode
    interpreter) — always under the executor's lane ``vmap``.  The batch
    rule therefore sees the device's whole lane batch at once and runs ONE
    two-hop routed exchange for it (:meth:`DistShardedBackend._route_chunk`,
    bucket capacity = the lane batch), instead of resolving against a
    gathered full-index view.  Every argument is passed explicitly (same
    idiom as ``kernels.mv_region_resolve.ops``): the index/state arrays
    arrive unbatched, only ``loc``/``reader`` carry the lane axis.

    SPMD alignment: all devices execute the same per-lane program with the
    same static lane count, so each traced batch-rule site issues exactly
    one collective on every device (vmapped ``lax.switch``/``cond`` execute
    all branches under a batched predicate — no device can skip a site).
    """
    from jax import custom_batching

    @custom_batching.custom_vmap
    def routed_read(keys, packed, starts, version, estimate, incarnation,
                    loc, reader):
        index = ShardedIndex(keys=keys, packed=packed, starts=starts,
                             version=version)
        res = backend._route_chunk(index, estimate, incarnation, w,
                                   loc[None], reader[None])
        return jax.tree_util.tree_map(lambda a: a[0], res)

    @routed_read.def_vmap
    def _batch_rule(axis_size, in_batched, keys, packed, starts, version,
                    estimate, incarnation, locs, readers):
        # The MV index/state arrays are lane-INVARIANT (one index serves the
        # whole wave), but they often arrive batched anyway: a vmapped
        # ``lax.switch`` (the bytecode ALU dispatch) broadcasts every branch
        # operand along the lane axis.  Those batched copies are literal
        # broadcasts, so lane 0 IS the shared array.
        unb = lambda x, b: x[0] if b else x
        keys, packed, starts, version, estimate, incarnation = (
            unb(x, b) for x, b in zip(
                (keys, packed, starts, version, estimate, incarnation),
                in_batched[:6]))
        if not in_batched[6]:
            locs = jnp.broadcast_to(locs, (axis_size,))
        if not in_batched[7]:
            readers = jnp.broadcast_to(readers, (axis_size,))
        index = ShardedIndex(keys=keys, packed=packed, starts=starts,
                             version=version)
        res = backend._route_chunk(index, estimate, incarnation, w,
                                   locs, readers)
        return res, jax.tree_util.tree_map(lambda _: True, res)

    return routed_read


@dataclasses.dataclass(frozen=True)
class DistShardedBackend(BackendDefaults):
    """Sharded MV backend with regions placed on a 1-D device mesh.

    Every method must execute inside ``shard_map`` over the ``'regions'``
    axis (:func:`repro.core.dist.engine.run_block_dist` provides it); the
    index pytree it builds/updates is the per-device LOCAL view.
    """

    n_txns: int
    n_locs: int
    n_shards: int            # global region count S (single-device plan)
    shard_size: int
    n_devices: int           # mesh size D
    regions_per_device: int  # ceil(S / D)
    resolver_impl: str = "xla"
    name: str = dataclasses.field(default="dist", init=False)

    @classmethod
    def from_config(cls, cfg) -> "DistShardedBackend":
        plan = plan_for(cfg.n_locs, cfg.n_txns, cfg.n_shards,
                        resolve_mesh(cfg).devices.size)
        return cls(n_txns=cfg.n_txns, n_locs=cfg.n_locs,
                   n_shards=plan.n_regions, shard_size=plan.shard_size,
                   n_devices=plan.n_devices,
                   regions_per_device=plan.regions_per_device,
                   resolver_impl=cfg.resolver_impl)

    # -- placement helpers --------------------------------------------------

    @property
    def span(self) -> int:
        """Contiguous locations owned by one device."""
        return self.regions_per_device * self.shard_size

    @property
    def _local(self) -> ShardedBackend:
        """The per-device single-device backend (identical on every device:
        ``regions_per_device`` regions of ``shard_size`` locations)."""
        return ShardedBackend(n_txns=self.n_txns, n_locs=self.span,
                              n_shards=self.regions_per_device,
                              shard_size=self.shard_size,
                              resolver_impl=self.resolver_impl)

    def _base(self) -> jax.Array:
        """This device's first owned location (traced; inside shard_map)."""
        return jax.lax.axis_index(AXIS).astype(jnp.int32) * self.span

    def _localize(self, locs: jax.Array, base: jax.Array) -> jax.Array:
        """Global locations -> device-local ones; foreign/dead -> NO_LOC."""
        owned = (locs != NO_LOC) & (locs >= base) & (locs < base + self.span)
        return jnp.where(owned, locs - base, NO_LOC)

    # -- MVBackend protocol -------------------------------------------------

    @property
    def n_regions(self) -> int:
        return self.n_shards            # global: engine dirt masks are (S,)

    def region_of(self, locs: jax.Array) -> jax.Array:
        """Global location -> global region id (same map as ``sharded``)."""
        return jnp.clip(locs // self.shard_size, 0, self.n_shards - 1)

    def build(self, write_locs: jax.Array) -> ShardedIndex:
        return self._local.build(self._localize(write_locs, self._base()))

    def update(self, index: ShardedIndex, write_locs: jax.Array,
               txn_ids: jax.Array, old_write_locs: jax.Array,
               new_write_locs: jax.Array) -> tuple[ShardedIndex, jax.Array]:
        """Shard-local event merge: each device folds only the wave's write
        events that land in its regions (the same O(wave·log)+one-cumsum
        merge as single-device, on the local capacity).  The returned dirty
        mask is GLOBAL — it is a pure function of the replicated delta, so
        no communication is needed to agree on it."""
        base = self._base()
        local, _ = self._local.update(
            index, self._localize(write_locs, base), txn_ids,
            self._localize(old_write_locs, base),
            self._localize(new_write_locs, base))
        dirty = dirty_from_delta(self.n_shards, self.region_of,
                                 old_write_locs, new_write_locs)
        return local, dirty

    def make_resolver(self, index: ShardedIndex, write_locs: jax.Array,
                      estimate: jax.Array, incarnation: jax.Array):
        """Scalar resolver over the ``all_gather``ed full index view.

        Used by the execute phase, whose reads surface one at a time inside
        the transaction VM's scan and therefore cannot be bucket-routed.
        The gathered view is the per-device flat lists concatenated in
        device order, so a global region ``s`` lives at device ``d = s //
        regions_per_device`` with segment bounds offset by ``d * cap``;
        segment contents (keys and packed entries) are byte-identical to the
        single-device index, hence so is every resolution.
        """
        keys = jax.lax.all_gather(index.keys, AXIS).reshape(-1)
        packed = jax.lax.all_gather(index.packed, AXIS).reshape(-1)
        starts = jax.lax.all_gather(index.starts, AXIS)   # (D, SL+1)
        cap = index.keys.shape[0]
        n1 = self.n_txns + 1
        w = write_locs.shape[1]
        search = select_search(self.resolver_impl)

        def resolver(loc, reader):
            in_universe = (loc >= 0) & (loc < self.n_locs)
            s = self.region_of(loc)
            d = s // self.regions_per_device
            ls = s - d * self.regions_per_device
            lo = d * cap + starts[d, ls]
            hi = d * cap + starts[d, ls + 1]
            local = loc - s * self.shard_size
            pos = search(keys, lo, hi, local * n1 + reader) - 1
            safe = jnp.clip(pos, 0, keys.shape[0] - 1)
            key = keys[safe]
            entry = packed[safe]
            found = (pos >= lo) & (key // n1 == local) & in_universe
            return finalize_resolution(found, entry // w, entry % w,
                                       estimate, incarnation)

        return resolver

    # -- batched/placement hooks --------------------------------------------

    def _answer_local(self, index: ShardedIndex, locs: jax.Array,
                      readers: jax.Array, estimate: jax.Array,
                      incarnation: jax.Array, w: int) -> ReadResolution:
        """Answer a query batch against THIS device's segments only.

        Queries whose region this device does not own (or that are out of
        universe / NO_LOC) come back ``found=False`` — the shared owner-side
        kernel of the routed resolve and the span-local snapshot.
        """
        SL = self.regions_per_device
        me = jax.lax.axis_index(AXIS).astype(jnp.int32)
        n1 = self.n_txns + 1
        search = select_search(self.resolver_impl)
        s = self.region_of(locs)
        ls = s - me * SL
        mine = (locs >= 0) & (locs < self.n_locs) & (ls >= 0) & (ls < SL)
        lss = jnp.clip(ls, 0, SL - 1)
        lo = index.starts[lss]
        hi = index.starts[lss + 1]
        local_loc = locs - s * self.shard_size
        q = local_loc * n1 + readers
        pos = jax.vmap(lambda l, h, k: search(index.keys, l, h, k)
                       )(lo, hi, q) - 1
        safe = jnp.clip(pos, 0, index.keys.shape[0] - 1)
        key = index.keys[safe]
        entry = index.packed[safe]
        found = mine & (pos >= lo) & (key // n1 == local_loc)
        return finalize_resolution(found, entry // w, entry % w,
                                   estimate, incarnation)

    def _route_chunk(self, index: ShardedIndex, estimate: jax.Array,
                     incarnation: jax.Array, w: int, my_locs: jax.Array,
                     my_rdrs: jax.Array) -> ReadResolution:
        """Answer THIS device's ``(qc,)`` query chunk by two-hop routing.

        The shared core of :meth:`resolve_batch` and the execute phase's
        per-read routed resolver: bucket the chunk by owning device, route
        with one ``all_to_all``, answer foreign queries against the local
        segments, route the answers back.  Bucket capacity equals the chunk
        size (a device can send at most its whole chunk to one owner), so
        routing never overflows and needs no fallback path.  Returns the
        chunk's answers in query order.
        """
        D, SL = self.n_devices, self.regions_per_device
        i32 = jnp.int32
        qc = my_locs.shape[0]

        # Bucket by owning device; rank within bucket = stable order of the
        # chunk (sort-based cumcount, same group trick as sharded.update).
        owner = self.region_of(my_locs) // SL
        order = jnp.argsort(owner, stable=True)
        so = owner[order]
        iw = jnp.arange(qc, dtype=i32)
        grp_new = (iw == 0) | (so != jnp.roll(so, 1))
        srank = iw - jax.lax.cummax(jnp.where(grp_new, iw, 0))
        rank = jnp.zeros((qc,), i32).at[order].set(srank)
        slot = owner.astype(i32) * qc + rank          # unique in [0, D*qc)

        send_locs = jnp.full((D * qc,), NO_LOC, i32).at[slot].set(my_locs)
        send_rdrs = jnp.zeros((D * qc,), i32).at[slot].set(my_rdrs)
        a2a = lambda a: jax.lax.all_to_all(a.reshape(D, qc), AXIS, 0, 0)
        recv_locs = a2a(send_locs).reshape(-1)
        recv_rdrs = a2a(send_rdrs).reshape(-1)

        res = self._answer_local(index, recv_locs, recv_rdrs, estimate,
                                 incarnation, w)
        # Route answers back and unpermute: my query i's answer sits at
        # back[owner[i]*qc + rank[i]].
        return jax.tree_util.tree_map(lambda a: a2a(a).reshape(-1)[slot], res)

    def resolve_batch(self, index: ShardedIndex, write_locs: jax.Array,
                      estimate: jax.Array, incarnation: jax.Array,
                      locs: jax.Array, readers: jax.Array) -> ReadResolution:
        """Two-hop routed query (see module docstring).

        The replicated ``(Q,)`` batch is chunked evenly across devices; each
        device routes its chunk's queries to their owning devices
        (:meth:`_route_chunk`) and the answered chunks are re-gathered, so
        both the search work and the answer traffic split D ways.
        """
        D = self.n_devices
        i32 = jnp.int32
        w = write_locs.shape[1]
        Q = locs.shape[0]
        qc = -(-Q // D)                   # chunk (and bucket) capacity
        pad = qc * D - Q
        if pad:
            locs = jnp.concatenate([locs, jnp.full((pad,), NO_LOC, i32)])
            readers = jnp.concatenate([readers, jnp.zeros((pad,), i32)])
        me = jax.lax.axis_index(AXIS)
        my_locs = jax.lax.dynamic_slice_in_dim(locs, me * qc, qc)
        my_rdrs = jax.lax.dynamic_slice_in_dim(readers, me * qc, qc)
        back = self._route_chunk(index, estimate, incarnation, w,
                                 my_locs, my_rdrs)
        return jax.tree_util.tree_map(
            lambda a: jax.lax.all_gather(a, AXIS).reshape(-1)[:Q], back)

    def make_routed_resolver(self, index: ShardedIndex,
                             write_locs: jax.Array, estimate: jax.Array,
                             incarnation: jax.Array):
        """Scalar resolver whose lane-vmapped calls route, not gather.

        Same ``resolver(loc, reader)`` contract as :meth:`make_resolver`
        and byte-identical answers (both end in the same per-segment
        search), but the communication pattern is the two-hop routed
        exchange of :func:`_routed_read_fn` — per-device traffic scales
        with the device's lane count, not with the index.
        """
        routed = _routed_read_fn(self, write_locs.shape[1])

        def resolver(loc, reader):
            return routed(index.keys, index.packed, index.starts,
                          index.version, estimate, incarnation, loc, reader)

        return resolver

    def _my_lane_slice(self, active_ids: jax.Array) -> jax.Array:
        """This device's ``ceil(window/D)`` slice of the wave's lanes.

        The wave is padded with fill lanes (txn id ``n_txns``) to a
        D-divisible width, so every device executes the same static lane
        count — a device whose slice is all fill lanes still participates
        in every routed exchange (SPMD alignment).
        """
        D = self.n_devices
        win = active_ids.shape[0]
        lpd = -(-win // D)
        pad = lpd * D - win
        ids = active_ids
        if pad:
            ids = jnp.concatenate(
                [ids, jnp.full((pad,), self.n_txns, jnp.int32)])
        me = jax.lax.axis_index(AXIS)
        return jax.lax.dynamic_slice_in_dim(ids, me * lpd, lpd)

    def execute_routed(self, index: ShardedIndex, write_locs: jax.Array,
                       estimate: jax.Array, incarnation: jax.Array,
                       active_ids: jax.Array, exec_fn):
        """Partitioned wave execution (see module docstring).

        Each device runs ``exec_fn`` over only its lane slice, reading
        through the routed per-read resolver; one ``all_gather`` re-
        replicates the wave's ``ExecResult`` lanes in preset order.  Exact
        by construction: the lane -> txn assignment is the replicated
        schedule, the routed answers are byte-identical to the local
        resolver's (same segments, same search), and fill/pad lanes beyond
        ``window`` are sliced off after the gather — so the gathered result
        is byte-identical to every device executing the full wave.
        """
        D = self.n_devices
        win = active_ids.shape[0]
        lpd = -(-win // D)
        my_ids = self._my_lane_slice(active_ids)
        resolver = self.make_routed_resolver(index, write_locs, estimate,
                                             incarnation)
        local = exec_fn(resolver, my_ids)
        gather = lambda a: jax.lax.all_gather(a, AXIS).reshape(
            (D * lpd,) + a.shape[1:])[:win]
        return jax.tree_util.tree_map(gather, local)

    def snapshot(self, index: ShardedIndex, write_locs: jax.Array,
                 estimate: jax.Array, incarnation: jax.Array,
                 write_vals: jax.Array, storage: jax.Array,
                 n_locs: int) -> jax.Array:
        """Span-local snapshot + one value gather (no query routing: device
        ``d``'s slice of the snapshot reads exactly the locations it owns).
        Tail-device phantom locations resolve to garbage and are sliced off
        by the final ``[:n_locs]``."""
        locs = self._base() + jnp.arange(self.span, dtype=jnp.int32)
        readers = jnp.full((self.span,), self.n_txns, jnp.int32)
        res = self._answer_local(index, locs, readers, estimate, incarnation,
                                 write_vals.shape[1])
        vals = resolve_value(write_vals, storage, res, locs)
        return jax.lax.all_gather(vals, AXIS).reshape(-1)[:n_locs]

    def version_view(self, index: ShardedIndex) -> jax.Array:
        """Replicate the per-region version counters: one ``(S,)``-sized
        ``all_gather`` — the only state validation needs from other devices
        to decide the dirty-region skip."""
        g = jax.lax.all_gather(index.version, AXIS).reshape(-1)
        return g[:self.n_shards]

    def bump_versions(self, index: ShardedIndex,
                      dirty: jax.Array) -> ShardedIndex:
        """Apply this device's slice of a global dirty mask to its local
        counters (engine-side bumps for validation-abort estimate flips)."""
        SL = self.regions_per_device
        pad = self.n_devices * SL - self.n_shards
        d = dirty.astype(jnp.int32)
        if pad:
            d = jnp.concatenate([d, jnp.zeros((pad,), jnp.int32)])
        me = jax.lax.axis_index(AXIS)
        mine = jax.lax.dynamic_slice_in_dim(d, me * SL, SL)
        return index._replace(version=index.version + mine)

    def trace_index_size(self, index: ShardedIndex,
                         write_locs: jax.Array) -> jax.Array:
        """Device-LOCAL CSR occupancy — deliberately not a collective: the
        wave trace keeps the per-device counts and merges them into a
        ``(D, cap)`` view on block exit (``obs.trace.merge_device_traces``),
        which is the region load-balance telemetry."""
        return index.starts[-1]

    def trace_dirty_count(self, dirty: jax.Array) -> jax.Array:
        """Count only the device's own slice of the global dirty mask (the
        same span arithmetic as :meth:`bump_versions`), so the merged trace
        shows per-device write traffic rather than D copies of the global
        count."""
        SL = self.regions_per_device
        pad = self.n_devices * SL - self.n_shards
        d = dirty.astype(jnp.int32)
        if pad:
            d = jnp.concatenate([d, jnp.zeros((pad,), jnp.int32)])
        me = jax.lax.axis_index(AXIS)
        return jax.lax.dynamic_slice_in_dim(d, me * SL, SL).sum(
            dtype=jnp.int32)

    def guard_index_ok(self, index: ShardedIndex,
                       write_locs: jax.Array) -> jax.Array:
        """Device-LOCAL structural check: delegate to the per-device
        single-device backend over the localized write set (the same
        localization ``build``/``update`` use), so the conservation law is
        checked per shard — deliberately not a collective; the engine's
        guard report is replicated-AND-merged on block exit
        (``repro.guard.invariants.merge_device_reports``)."""
        return self._local.guard_index_ok(
            index, self._localize(write_locs, self._base()))

    def trace_exec_lanes(self, active_ids: jax.Array,
                         active_mask: jax.Array) -> jax.Array:
        """Live lanes THIS device executed — its slice of the partitioned
        wave (:meth:`execute_routed`'s padding and slicing arithmetic), so
        the merged ``(D, cap)`` buffer is the execute-phase load balance."""
        return (self._my_lane_slice(active_ids)
                < self.n_txns).sum(dtype=jnp.int32)

"""Static placement plan: which device owns which MV regions.

The global region partition is exactly the single-device ``shard_plan`` (so
the dist engine is region-structure-identical to the ``sharded`` backend it
must match byte-for-byte); devices then take *contiguous runs* of
``regions_per_device = ceil(n_regions / n_devices)`` regions each.  Region
counts that do not divide the device count leave the tail device with
phantom (always-empty) regions — padding, never a correctness case, because
no location maps into them.

Everything in this module is static trace-time Python; meshes are built
lazily so importing :mod:`repro.core.dist` never touches jax device state.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.mv.sharded import shard_plan

#: The one mesh axis name of the dist subsystem (1-D mesh over regions).
AXIS = "regions"


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Static region→device placement (pure trace-time Python)."""

    n_devices: int           # mesh size D
    n_regions: int           # global region count S (== shard_plan's)
    regions_per_device: int  # ceil(S / D); tail regions are phantom padding
    shard_size: int          # locations per region (== shard_plan's)

    @property
    def span(self) -> int:
        """Contiguous locations owned by one device."""
        return self.regions_per_device * self.shard_size


def plan_for(n_locs: int, n_txns: int, n_shards: int,
             n_devices: int) -> DistPlan:
    """Resolve the placement for a config's universe on ``n_devices``."""
    if n_devices < 1:
        raise ValueError(f"need n_devices >= 1, got {n_devices}")
    n_regions, shard_size = shard_plan(n_locs, n_txns, n_shards)
    return DistPlan(n_devices=n_devices, n_regions=n_regions,
                    regions_per_device=-(-n_regions // n_devices),
                    shard_size=shard_size)


def resolve_mesh(cfg) -> jax.sharding.Mesh:
    """The config's 1-D region mesh (lazily built over all devices if unset).

    Static per process for a given config: ``EngineConfig.mesh`` when given
    (validated at construction to be 1-D over ``('regions',)``), else one
    axis over every available device — the deterministic default that makes
    ``make_executor`` compile once per mesh.
    """
    if cfg.mesh is not None:
        return cfg.mesh
    from repro.launch.mesh import make_mesh
    return make_mesh(AXIS)

"""Multi-device Block-STM: MV regions shard_mapped across a device mesh.

The ``sharded`` backend's CSR-flat index is per-region independent — that
seam becomes physical here.  A 1-D mesh ``Mesh(('regions',))`` places each
region's index segment, its ``version`` counter, and its slice of the final
snapshot on a fixed device; the whole wave loop then runs as ONE
``jax.shard_map`` program (:func:`repro.core.dist.engine.run_block_dist`)
in which

* ``build``/``update`` are shard-local — each device event-merges only its
  own regions' write events (:class:`~repro.core.dist.backend
  .DistShardedBackend` delegates to a per-device
  :class:`~repro.core.mv.sharded.ShardedBackend`),
* batched read resolution (validation) is a two-hop routed query — queries
  bucketed by ``region_of(loc)``, ``all_to_all``'d to the owning device,
  answered with the existing segment search, routed back,
* the execute phase partitions each wave's lanes ``window/D`` per device;
  reads discovered mid-transaction cannot be pre-routed, so each per-lane
  read surfaces as the SAME two-hop routed exchange (a ``custom_vmap``
  batch rule over the device's lane batch), and one ``ExecResult``
  ``all_gather`` re-replicates the wave,
* validation's dirty-region skip consumes the replicated version vector via
  an ``all_gather`` of the ``(n_regions,)`` counters only, and
* the snapshot is computed per device over its own location span and
  gathered.

Everything enters through the ordinary :class:`~repro.core.mv.base.MVBackend`
protocol (plus its batched/placement hooks), so the engine's phase functions
run unchanged inside the shard_map — and the execution is EXACT: byte-
identical snapshots and identical abort/wave statistics to the single-device
``sharded`` backend (property-tested in ``tests/test_dist.py`` on 1/2/8
virtual devices).

Importing this package never touches jax device state; meshes are built
lazily (:func:`repro.launch.mesh.make_mesh`) at trace time.  Enable with
``EngineConfig(dist=True, backend='sharded'[, mesh=...])`` or
``executor.run_engine(..., mesh=...)``.
"""
from __future__ import annotations

from repro.core.dist.backend import DistShardedBackend
from repro.core.dist.plan import AXIS, DistPlan, plan_for, resolve_mesh

__all__ = ["AXIS", "DistPlan", "DistShardedBackend", "plan_for",
           "resolve_mesh"]

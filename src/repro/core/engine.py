"""Block-STM wave engine.

Executes a block of transactions speculatively and in parallel, producing the
state of a sequential execution in the preset order (paper §2-3), as a single
jittable JAX program:

    wave := select lowest-index pending txns (window = #virtual threads)
          -> vmap-execute them against the multi-version memory snapshot
          -> apply write sets / register dependencies (ESTIMATE hits)
          -> merge the wave's write-set delta into the multi-version index
             (``backend.update``; per-region dirty tracking — or a full
             ``backend.build`` rebuild under ``mv_update='rebuild'``)
          -> validate executed txns' read sets against the new index —
             skipping rows whose every read region is version-clean since
             they last validated (``dirty_validation``)
          -> abort failures (write sets become ESTIMATEs)
          -> advance the commit frontier (longest executed&valid prefix)

The incremental paths mirror the paper's collaborative scheduler: MVMemory is
updated in place per write-set (Algorithm 2 ``record``) and validation work
concentrates on what might have changed (the ``validation_idx`` intuition),
so per-wave cost tracks the wave, not the block.  Both are exact: the
incremental index is byte-identical to a fresh build, and a skipped row is
one whose reads provably resolve to the same versions they validated against
(``tests/test_mv_incremental.py`` property-tests both equivalences).

The loop is a ``lax.while_loop`` over :class:`EngineState`; determinism is
structural (no atomics, no races) and equivalence to the sequential execution
is property-tested in ``tests/test_engine_equivalence.py``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import executor, mv
from repro.guard import chaos as guard_chaos
from repro.guard import degrade as guard_degrade
from repro.guard import invariants as guard_inv
from repro.core.types import (NO_LOC, STORAGE, BlockResult, BlockStats,
                              EngineConfig, EngineState, ExecResult)
from repro.core.vm import TxnProgram


def _named_phase(name: str):
    """Wrap a phase fn in ``jax.named_scope`` so its ops carry the phase
    name in the HLO name stack — the profiler timeline (``make profile``)
    groups per-phase work under these labels.  Metadata only: the compiled
    program is unchanged."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with jax.named_scope(name):
                return fn(*args, **kwargs)
        return wrapped
    return deco


def _skip_enabled(cfg: EngineConfig) -> bool:
    """Dirty-region validation skip: needs region versions (incremental
    update) and the full-validation regime (windowed validation already
    bounds per-wave work its own way)."""
    return (cfg.dirty_validation and cfg.mv_update == "incremental"
            and (cfg.validation_window <= 0
                 or cfg.validation_window >= cfg.n_txns))


def _init_state(cfg: EngineConfig) -> EngineState:
    n, w, r = cfg.n_txns, cfg.max_writes, cfg.max_reads
    backend = mv.make_backend(cfg)
    return EngineState(
        write_locs=jnp.full((n, w), NO_LOC, jnp.int32),
        write_vals=jnp.zeros((n, w), cfg.value_dtype),
        estimate=jnp.zeros((n,), jnp.bool_),
        read_locs=jnp.full((n, r), NO_LOC, jnp.int32),
        read_writer=jnp.full((n, r), STORAGE, jnp.int32),
        read_inc=jnp.full((n, r), -1, jnp.int32),
        read_region_ver=jnp.zeros((n, r), jnp.int32),
        incarnation=jnp.zeros((n,), jnp.int32),
        executed=jnp.zeros((n,), jnp.bool_),
        needs_exec=jnp.ones((n,), jnp.bool_),
        blocked_by=jnp.full((n,), -1, jnp.int32),
        frontier=jnp.asarray(0, jnp.int32),
        wave=jnp.asarray(0, jnp.int32),
        index=backend.build(jnp.full((n, w), NO_LOC, jnp.int32)),
        stat_execs=jnp.asarray(0, jnp.int32),
        stat_dep_aborts=jnp.asarray(0, jnp.int32),
        stat_val_aborts=jnp.asarray(0, jnp.int32),
        stat_wrote_new=jnp.asarray(0, jnp.int32),
        trace=obs.init_trace(cfg),
        guard=guard_inv.init_report(cfg),
    )


def _select_wave(state: EngineState, cfg: EngineConfig) -> tuple[jax.Array, jax.Array]:
    """Pick the ``window`` lowest-index eligible transactions.

    This is the BSP analogue of the paper's ``execution_idx`` counter: threads
    always claim the lowest READY_TO_EXECUTE transaction.  A txn blocked on a
    dependency is ineligible until its blocker has re-executed (paper:
    ``resume_dependencies``).
    """
    n = cfg.n_txns
    safe_blocker = jnp.clip(state.blocked_by, 0, n - 1)
    dep_resolved = (state.blocked_by < 0) | state.executed[safe_blocker]
    eligible = state.needs_exec & dep_resolved
    # First `window` eligible indices: nonzero(size=) is O(n) (cumsum+scatter)
    # vs the O(n log n) argsort it replaces (§Perf iteration 3).  Fill lanes
    # stay OUT-OF-BOUNDS (= n): XLA clips them on gather (garbage lanes are
    # masked) and drops them on scatter — keeping in-bounds indices unique.
    (active_ids,) = jnp.nonzero(eligible, size=cfg.window, fill_value=n)
    active_mask = active_ids < n
    return active_ids.astype(jnp.int32), active_mask


def _execute_wave(state: EngineState, active_ids: jax.Array,
                  program: TxnProgram, params: Any, storage: jax.Array,
                  cfg: EngineConfig) -> ExecResult:
    """vmap the VM over the wave; reads resolve against the wave-start index.

    Dispatch over program representations (Python-DSL vs bytecode
    ``execute_spec`` objects) lives in the shared executor protocol
    (:func:`repro.core.vm.make_exec_one` via
    :func:`repro.core.executor.execute_txns`), which the Bohm/LiTM baselines
    use as well — one code path executes DSL and heterogeneous bytecode
    blocks under every engine.

    WHERE the lanes execute is the backend's ``execute_routed`` placement
    hook: single-device backends run every lane here against their plain
    resolver; the dist backend partitions the lanes across the region mesh
    and re-replicates the result (:mod:`repro.core.dist.backend`).
    """
    def exec_fn(resolver, ids):
        return executor.execute_txns(program, params, storage, cfg, resolver,
                                     state.write_vals, ids)

    return mv.make_backend(cfg).execute_routed(
        state.index, state.write_locs, state.estimate, state.incarnation,
        active_ids, exec_fn)


def _apply_results(state: EngineState, active_ids: jax.Array,
                   active_mask: jax.Array, res: ExecResult,
                   cfg: EngineConfig) -> EngineState:
    """Record finished incarnations (paper: MVMemory.record + finish_execution)
    and register dependencies for ESTIMATE-blocked executions
    (paper: add_dependency)."""
    success = active_mask & ~res.blocked
    blocked = active_mask & res.blocked

    old_wlocs = state.write_locs[active_ids]
    # wrote_new_location (paper L35): any live new loc absent from the old set.
    new_live = res.write_locs != NO_LOC
    in_old = (res.write_locs[:, :, None] == old_wlocs[:, None, :]).any(-1)
    wrote_new = (new_live & ~in_old).any(-1)

    sel = lambda m, a, b: jnp.where(m[:, None] if a.ndim == 2 else m, a, b)
    upd = lambda arr, new: arr.at[active_ids].set(
        sel(success, new, arr[active_ids]))

    state = state._replace(
        write_locs=upd(state.write_locs, res.write_locs),
        write_vals=upd(state.write_vals, res.write_vals),
        read_locs=upd(state.read_locs, res.read_locs),
        read_writer=upd(state.read_writer, res.read_writer),
        read_inc=upd(state.read_inc, res.read_inc),
        estimate=state.estimate.at[active_ids].set(
            jnp.where(success, False, state.estimate[active_ids])),
        incarnation=state.incarnation.at[active_ids].add(
            success.astype(jnp.int32)),
        executed=state.executed.at[active_ids].set(
            jnp.where(success, True, state.executed[active_ids])),
        needs_exec=state.needs_exec.at[active_ids].set(
            jnp.where(success, False, state.needs_exec[active_ids])),
        blocked_by=state.blocked_by.at[active_ids].set(
            jnp.where(blocked, res.blocker,
                      jnp.where(success, -1, state.blocked_by[active_ids]))),
        stat_execs=state.stat_execs + success.sum(dtype=jnp.int32),
        stat_dep_aborts=state.stat_dep_aborts + blocked.sum(dtype=jnp.int32),
        stat_wrote_new=state.stat_wrote_new
        + (success & wrote_new).sum(dtype=jnp.int32),
    )
    return state


def _read_set_valid(state: EngineState, cfg: EngineConfig, read_locs,
                    read_writer, read_inc, readers) -> jax.Array:
    """validate_read_set (paper L62-72), vectorized over rows.

    The (rows, R) read matrix is flattened to ONE flat batch through the
    backend's ``resolve_batch`` hook, so batched resolver implementations —
    ``resolver_impl='pallas'`` (a custom_vmap whose batch rule is the
    region-resolve kernel) and the dist backend's two-hop routed query —
    see a single flat batch instead of a nested one.
    """
    backend = mv.make_backend(cfg)
    flat = backend.resolve_batch(state.index, state.write_locs,
                                 state.estimate, state.incarnation,
                                 read_locs.reshape(-1), readers.reshape(-1))
    res = jax.tree_util.tree_map(lambda a: a.reshape(read_locs.shape), flat)
    empty = read_locs == NO_LOC
    was_storage = read_writer == STORAGE
    ok_storage = was_storage & ~res.found                       # L68
    ok_mv = (~was_storage) & res.found & ~res.is_estimate \
        & (res.writer == read_writer) & (res.inc == read_inc)   # L70
    read_ok = empty | jnp.where(was_storage, ok_storage, ok_mv)
    read_ok = read_ok & ~(res.is_estimate & ~empty)              # L67
    return read_ok.all(axis=-1)


def _validate_dirty(state: EngineState, cfg: EngineConfig,
                    cur: jax.Array) -> tuple[jax.Array, obs.ValTraceAux,
                                             jax.Array | None]:
    """Full-validation semantics at dirty-row cost (dirty-region skip).

    A row may skip validation iff, for every live read, the version of the
    read location's region equals the version the row last validated against
    (``read_region_ver``).  Version bumps cover every way a resolution can
    change — index-entry changes via ``backend.update``'s dirty regions,
    estimate/incarnation restamps via the writer's own write regions (update
    for re-executions, :func:`_validate_all`'s post-abort bump for validation
    failures) — so a clean row would revalidate to exactly its recorded
    reads: skipping it is not an approximation.

    The rows that do need work are gathered into a ``cfg.dirty_cap()``-row
    batch (same O(n) nonzero machinery as the wave selection); waves that
    dirty more rows than the cap fall back to the full O(n·R) pass via
    ``lax.cond``, so the skip is never unsound and never more than one full
    validation.  ``cur`` is the current global region-version vector (the
    caller's ``version_view`` — computed once per wave, since gathering it
    is a collective under the dist backend).  Returns the ``(n,)`` fail
    mask plus the wave's skip telemetry
    (:class:`~repro.obs.trace.ValTraceAux` — dead, and DCE'd, whenever the
    wave trace does not consume it) plus, at ``guard_level >= 2``, the
    dirty-skip shadow count: a full validation pass runs alongside and
    counts the rows the version test calls clean that the full pass would
    fail — any nonzero count is an unsound skip (``None`` below level 2).
    """
    n, r = cfg.n_txns, cfg.max_reads
    backend = mv.make_backend(cfg)
    regions = backend.region_of(state.read_locs)
    live = state.read_locs != NO_LOC
    stale_read = live & (state.read_region_ver != cur[regions])
    need = state.executed & stale_read.any(axis=-1)
    n_need = need.sum()
    k = cfg.dirty_cap()

    def aux(fallback: jax.Array) -> obs.ValTraceAux:
        lanes = jnp.where(fallback, n * r, k * r)
        return obs.ValTraceAux(
            val_reads=lanes.astype(jnp.int32),
            skip_hits=(state.executed & ~need).sum(dtype=jnp.int32),
            skip_misses=n_need.astype(jnp.int32),
            skip_fallback=fallback)

    def full_path(_):
        readers = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None],
                                   (n, r))
        valid = _read_set_valid(state, cfg, state.read_locs,
                                state.read_writer, state.read_inc, readers)
        return state.executed & ~valid

    def shadow_viol(fail_full: jax.Array) -> jax.Array | None:
        # guard_level 2: rows the version test exonerates must pass a full
        # validation — the dirty-skip soundness invariant, checked against
        # the full verdict regardless of which path the engine took.
        if cfg.guard_level < 2:
            return None
        return (fail_full & ~need).sum(dtype=jnp.int32)

    if k >= n:
        # A capacity covering every row can never narrow the work: the cond
        # predicate would always take the gather path, paying its
        # nonzero/gather/scatter machinery on top of full-width validation.
        # This is the cap DISABLED, not the cap overflowing — report
        # fallback=False so small blocks don't show a 100% cap-fallback
        # rate in the wave trace (lane accounting is unaffected: k == n
        # here since dirty_cap() is clamped to n_txns, so k*r == n*r).
        fail = full_path(None)
        return fail, aux(jnp.asarray(False)), shadow_viol(fail)

    def gather_path(_):
        (rows,) = jnp.nonzero(need, size=k, fill_value=n)
        rows = rows.astype(jnp.int32)
        readers = jnp.broadcast_to(rows[:, None], (k, r))
        # Fill lanes (= n) gather-clip to row n-1 and produce garbage
        # verdicts; the scatter drops them (out-of-bounds row n).
        valid_k = _read_set_valid(state, cfg, state.read_locs[rows],
                                  state.read_writer[rows],
                                  state.read_inc[rows], readers)
        return jnp.zeros((n,), jnp.bool_).at[rows].set(~valid_k,
                                                       mode="drop") & need

    if cfg.guard_level >= 2:
        # The shadow pass needs the full verdict anyway; reuse it as the
        # fallback branch's answer (the gather path stays on the cond so
        # its machinery remains exercised — and checked — under guard).
        fail_full = full_path(None)
        fail = jax.lax.cond(n_need <= k, gather_path,
                            lambda _: fail_full, None)
        return fail, aux(n_need > k), shadow_viol(fail_full)
    fail = jax.lax.cond(n_need <= k, gather_path, full_path, None)
    return fail, aux(n_need > k), None


@_named_phase("blockstm.validate")
def _validate_all(state: EngineState, cfg: EngineConfig) -> EngineState:
    """Validate executed txns against the fresh index (paper:
    validate_read_set + finish_validation).

    With ``validation_window == 0`` every executed txn is re-validated each
    wave (conservative BSP) — unless ``dirty_validation`` holds, in which
    case rows whose every read region is version-clean since their last
    validation are skipped with unchanged semantics (:func:`_validate_dirty`).
    With ``vw > 0`` only the txns in [frontier, frontier + vw) are validated
    — the BSP analogue of the paper's ``validation_idx`` sweep: validation
    effort concentrates just above the commit frontier and moves up with it.
    Safety is unchanged because the frontier only ever advances across txns
    validated in the current wave.
    """
    n, r = cfg.n_txns, cfg.max_reads
    vw = cfg.validation_window
    skip = _skip_enabled(cfg)
    # One version gather serves the whole wave's validation (it is a
    # collective under the dist backend — don't re-issue it per use).
    cur = mv.make_backend(cfg).version_view(state.index) if skip else None
    vaux = None
    skip_viol = None
    if vw <= 0 or vw >= n:
        if skip:
            fail, vaux, skip_viol = _validate_dirty(state, cfg, cur)
        else:
            readers = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None],
                                       (n, r))
            valid = _read_set_valid(state, cfg, state.read_locs,
                                    state.read_writer, state.read_inc,
                                    readers)
            fail = state.executed & ~valid
            if cfg.trace_level:
                vaux = obs.ValTraceAux(
                    val_reads=jnp.asarray(n * r, jnp.int32),
                    skip_hits=jnp.asarray(0, jnp.int32),
                    skip_misses=state.executed.sum(dtype=jnp.int32),
                    skip_fallback=jnp.asarray(False))
        ok_for_commit = state.executed & ~fail
    else:
        start = jnp.minimum(state.frontier, n - vw)
        rows = start + jnp.arange(vw, dtype=jnp.int32)
        readers = jnp.broadcast_to(rows[:, None], (vw, r))
        valid_w = _read_set_valid(
            state, cfg,
            jax.lax.dynamic_slice_in_dim(state.read_locs, start, vw),
            jax.lax.dynamic_slice_in_dim(state.read_writer, start, vw),
            jax.lax.dynamic_slice_in_dim(state.read_inc, start, vw),
            readers)
        fail = jnp.zeros((n,), jnp.bool_).at[rows].set(~valid_w)
        fail = fail & state.executed
        # only txns validated THIS wave (or already committed) may commit
        in_window = jnp.zeros((n,), jnp.bool_).at[rows].set(True)
        below = jnp.arange(n, dtype=jnp.int32) < state.frontier
        ok_for_commit = state.executed & ~fail & (in_window | below)
        if cfg.trace_level:
            vaux = obs.ValTraceAux(
                val_reads=jnp.asarray(vw * r, jnp.int32),
                skip_hits=jnp.asarray(0, jnp.int32),
                skip_misses=(state.executed & in_window).sum(dtype=jnp.int32),
                skip_fallback=jnp.asarray(False))

    defer = None
    if cfg.chaos is not None:
        # Chaos rides the genuine abort machinery: extra failures flow into
        # ``fail`` BEFORE the skip bookkeeping below, so estimate flips,
        # region bumps, and re-execution scheduling happen exactly as for a
        # real validation failure.  Deferred rows get no verdict at all this
        # wave — removed from fail AND from commit eligibility.
        extra, defer = guard_chaos.validation_perturb(state, cfg)
        fail = (fail | extra) & ~defer
        ok_for_commit = ok_for_commit & ~extra & ~defer

    if skip:
        backend = mv.make_backend(cfg)
        regions = backend.region_of(state.read_locs)
        # Rows that remain executed were either validated this wave or
        # provably clean — either way their reads are now known to resolve
        # under the CURRENT (pre-bump) region versions.  A chaos-deferred
        # row got NO verdict, so its stamps must stay stale (refreshing
        # them would make a deferred genuine failure skip as clean —
        # unsound).
        ok_rows = state.executed & ~fail
        if defer is not None:
            ok_rows = ok_rows & ~defer
        rrv = jnp.where(ok_rows[:, None], cur[regions],
                        state.read_region_ver)
        # A validation abort flips the failing txn's write set to ESTIMATE
        # without touching any index entry: bump its write regions so rows
        # reading them revalidate next wave (bump AFTER the rrv refresh —
        # this wave validated against the pre-flip stamps).
        flocs = jnp.where(fail[:, None], state.write_locs, NO_LOC)
        bump = mv.dirty_from_delta(backend.n_regions, backend.region_of,
                                   flocs, flocs)
        state = state._replace(
            read_region_ver=rrv,
            index=backend.bump_versions(state.index, bump))

    state = state._replace(
        estimate=state.estimate | fail,
        executed=state.executed & ~fail,
        needs_exec=state.needs_exec | fail,
        stat_val_aborts=state.stat_val_aborts + fail.sum(dtype=jnp.int32),
    )
    # Commit frontier: longest validated-executed prefix (monotone).
    prefix = jnp.cumprod(ok_for_commit.astype(jnp.int32))
    frontier = jnp.maximum(state.frontier, prefix.sum().astype(jnp.int32))
    if cfg.trace_level:
        state = state._replace(trace=obs.record_validate(
            state.trace, state.wave, fail, frontier, vaux))
    if cfg.guard_level:
        # End-of-wave invariant sweep: state.frontier is still the pre-wave
        # value here, so the monotonicity check sees both sides.
        state = guard_inv.check_wave(state, cfg, frontier,
                                     skip_viol=skip_viol)
    return state._replace(frontier=frontier)


class WaveDelta(NamedTuple):
    """One wave's write-set delta: what :func:`_index_phase` needs to merge
    the wave into the MV index incrementally (all no-op lanes carry txn id
    ``n`` / NO_LOC rows, so backends can scatter-and-drop blindly)."""

    txn_ids: jax.Array         # (window,) i32 successful lanes' txn ids, else n
    old_write_locs: jax.Array  # (window, W) pre-wave live write sets, else NO_LOC
    new_write_locs: jax.Array  # (window, W) fresh write sets, else NO_LOC
    read_locs: jax.Array       # (window, R) fresh read sets (raw lanes)
    ver0: jax.Array            # (n_regions,) index version the wave read
                               # against (global view; only materialized —
                               # and only consumed — under the
                               # dirty-validation skip)


@_named_phase("blockstm.execute")
def _execute_phase(state: EngineState, program: TxnProgram, params: Any,
                   storage: jax.Array,
                   cfg: EngineConfig) -> tuple[EngineState, WaveDelta]:
    """Select + execute + apply one wave; capture its delta for the index."""
    if cfg.chaos is not None:
        # Wave-start value corruption: garbage every unreachable (non-
        # executed) row's write values before anything reads this wave.
        state = guard_chaos.perturb_values(state, cfg)
    active_ids, active_mask = _select_wave(state, cfg)
    if cfg.chaos is not None:
        active_ids, active_mask = guard_chaos.stall_lanes(
            state, active_ids, active_mask, cfg)
    res = _execute_wave(state, active_ids, program, params, storage, cfg)
    success = active_mask & ~res.blocked
    delta = WaveDelta(
        txn_ids=jnp.where(success, active_ids, cfg.n_txns),
        old_write_locs=jnp.where(success[:, None],
                                 state.write_locs[active_ids], NO_LOC),
        new_write_locs=jnp.where(success[:, None], res.write_locs, NO_LOC),
        read_locs=res.read_locs,
        # Only the dirty-validation skip consumes ver0, and gathering the
        # global view is a collective under the dist backend: skip off ->
        # carry the raw (possibly device-local) counters unread.
        ver0=(mv.make_backend(cfg).version_view(state.index)
              if _skip_enabled(cfg) else state.index.version),
    )
    new_state = _apply_results(state, active_ids, active_mask, res, cfg)
    if cfg.trace_level:
        new_state = new_state._replace(trace=obs.record_execute(
            new_state.trace, state.wave, active_ids, active_mask,
            success, active_mask & res.blocked, res,
            mv.make_backend(cfg).trace_exec_lanes(active_ids, active_mask)))
    return new_state, delta


@_named_phase("blockstm.index")
def _index_phase(state: EngineState, delta: WaveDelta,
                 cfg: EngineConfig) -> EngineState:
    """Fold the wave into the MV index: incremental delta merge (default) or
    the full-rebuild reference path, plus per-read region-version recording
    for the dirty-validation skip."""
    backend = mv.make_backend(cfg)
    dirty = None
    if cfg.mv_update == "incremental":
        index, dirty = backend.update(state.index, state.write_locs,
                                      delta.txn_ids, delta.old_write_locs,
                                      delta.new_write_locs)
    else:
        index = backend.build(state.write_locs)
    state = state._replace(index=index)
    if _skip_enabled(cfg):
        # Fresh rows resolved their reads against the wave-start versions
        # (ver0); record those so validation can tell whether anything a row
        # read has since moved.  No-op lanes scatter to row n and drop.
        rrv = delta.ver0[backend.region_of(delta.read_locs)]
        state = state._replace(
            read_region_ver=state.read_region_ver.at[delta.txn_ids].set(
                rrv, mode="drop"))
    if cfg.trace_level:
        state = state._replace(trace=obs.record_index(
            state.trace, state.wave, backend, index, state.write_locs,
            dirty))
    return state


def _wave_step(state: EngineState, program: TxnProgram, params: Any,
               storage: jax.Array, cfg: EngineConfig) -> EngineState:
    state, delta = _execute_phase(state, program, params, storage, cfg)
    state = _index_phase(state, delta, cfg)
    state = _validate_all(state, cfg)
    return state._replace(wave=state.wave + 1)


@_named_phase("blockstm.snapshot")
def _snapshot(state: EngineState, storage: jax.Array,
              cfg: EngineConfig) -> jax.Array:
    """MVMemory.snapshot through the backend's batched ``snapshot`` hook
    (single-device: vmapped resolver; dist: span-local reads + gather)."""
    return mv.make_backend(cfg).snapshot(
        state.index, state.write_locs, state.estimate, state.incarnation,
        state.write_vals, storage, cfg.n_locs)


def run_block(program: TxnProgram, params: Any, storage: jax.Array,
              cfg: EngineConfig) -> BlockResult:
    """Execute one block under Block-STM semantics. Jit-compatible.

    ``cfg.dist`` routes to the multi-device engine — the SAME loop
    (:func:`_run_block_impl`) wrapped in one ``jax.shard_map`` over the
    config's region mesh (:mod:`repro.core.dist`), with the backend's
    protocol hooks supplying the collectives.
    """
    if cfg.dist:
        from repro.core.dist.engine import run_block_dist
        return run_block_dist(program, params, storage, cfg)
    return _run_block_impl(program, params, storage, cfg)


def _run_block_impl(program: TxnProgram, params: Any, storage: jax.Array,
                    cfg: EngineConfig) -> BlockResult:
    """The engine loop proper (single-device body; also the per-device
    program of the dist engine — see :func:`run_block`)."""
    state = _init_state(cfg)
    cap = jnp.asarray(cfg.waves_cap(), jnp.int32)

    def cond(s: EngineState):
        return (s.frontier < cfg.n_txns) & (s.wave < cap)

    def body(s: EngineState):
        return _wave_step(s, program, params, storage, cfg)

    state = jax.lax.while_loop(cond, body, state)
    snapshot, committed, degraded = _finish(state, program, params, storage,
                                            cfg)
    trace = state.trace
    if cfg.trace_level:
        trace = trace._replace(degraded=degraded)
    return BlockResult(
        snapshot=snapshot,
        committed=committed,
        degraded=degraded,
        waves=state.wave,
        execs=state.stat_execs,
        dep_aborts=state.stat_dep_aborts,
        val_aborts=state.stat_val_aborts,
        wrote_new=state.stat_wrote_new,
        trace=trace,
        guard=state.guard,
    )


def _finish(state: EngineState, program: TxnProgram, params: Any,
            storage: jax.Array,
            cfg: EngineConfig) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Post-loop exit: ``(snapshot, committed, degraded)``.

    The converged exit (``frontier == n``) snapshots the MV state as
    always.  A wave-cap exhaustion instead ``lax.cond``s into the guarded
    degradation path (:mod:`repro.guard.degrade`): the deterministic
    sequential executor commits the preset-order state — byte-identical to
    what a converged speculative run would have committed — unless the
    block is unsound even sequentially (a txn blocks on its own slot
    overflow), in which case ``committed=False`` with the partial
    speculative snapshot, exactly the old failure surface.  With
    ``degrade_on_stall=False`` the old exit is compiled unchanged.
    """
    done = state.frontier >= cfg.n_txns
    if not cfg.degrade_on_stall:
        return _snapshot(state, storage, cfg), done, jnp.asarray(False)

    def converged(_):
        return (_snapshot(state, storage, cfg), jnp.asarray(True),
                jnp.asarray(False))

    def degrade(_):
        seq, clean = guard_degrade.sequential_block(program, params,
                                                    storage, cfg)
        partial = _snapshot(state, storage, cfg)
        return jnp.where(clean, seq, partial), clean, clean

    return jax.lax.cond(done, converged, degrade, None)


def make_executor(program: TxnProgram, cfg: EngineConfig) -> Callable:
    """Jitted block executor: (params, storage) -> BlockResult."""
    @functools.partial(jax.jit, donate_argnums=())
    def run(params, storage):
        return run_block(program, params, storage, cfg)
    return run


def run_chain(program: TxnProgram, blocks_params: Any, storage: jax.Array,
              cfg: EngineConfig) -> tuple[jax.Array, BlockStats]:
    """Execute a CHAIN of blocks: each block's committed snapshot becomes the
    next block's storage (the blockchain validator loop; paper §1 "state is
    updated per block").  ``blocks_params`` leaves have a leading block axis.
    Jit-compatible: one compiled program executes the whole chain via scan.

    Returns ``(final_state, stats)`` where ``stats`` is a
    :class:`~repro.core.types.BlockStats` with one leading block axis per
    field — per-block counters come out typed, with no snapshot placeholder
    inflating the scan carry.

    Chain integrity: with ``cfg.degrade_on_stall`` (the default) a block
    that exhausts its wave budget still commits its preset-order state via
    the sequential fallback, flagged in ``stats.degraded`` for that block
    — the chain never silently feeds a partial snapshot forward.  Callers
    that disable degradation must check ``stats.committed`` themselves:
    a False entry means every later block executed from a partial state.
    """
    def step(st, params):
        res = run_block(program, params, st, cfg)
        return res.snapshot, res.stats()

    final_state, stats = jax.lax.scan(step, storage, blocks_params)
    return final_state, stats

"""Block-STM wave engine.

Executes a block of transactions speculatively and in parallel, producing the
state of a sequential execution in the preset order (paper §2-3), as a single
jittable JAX program:

    wave := select lowest-index pending txns (window = #virtual threads)
          -> vmap-execute them against the multi-version memory snapshot
          -> apply write sets / register dependencies (ESTIMATE hits)
          -> rebuild the sorted multi-version index
          -> validate every executed txn's read set against the new index
          -> abort failures (write sets become ESTIMATEs)
          -> advance the commit frontier (longest executed&valid prefix)

The loop is a ``lax.while_loop`` over :class:`EngineState`; determinism is
structural (no atomics, no races) and equivalence to the sequential execution
is property-tested in ``tests/test_engine_equivalence.py``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import executor, mv
from repro.core.types import (NO_LOC, STORAGE, BlockResult, BlockStats,
                              EngineConfig, EngineState, ExecResult)
from repro.core.vm import TxnProgram


def _init_state(cfg: EngineConfig) -> EngineState:
    n, w, r = cfg.n_txns, cfg.max_writes, cfg.max_reads
    backend = mv.make_backend(cfg)
    return EngineState(
        write_locs=jnp.full((n, w), NO_LOC, jnp.int32),
        write_vals=jnp.zeros((n, w), cfg.value_dtype),
        estimate=jnp.zeros((n,), jnp.bool_),
        read_locs=jnp.full((n, r), NO_LOC, jnp.int32),
        read_writer=jnp.full((n, r), STORAGE, jnp.int32),
        read_inc=jnp.full((n, r), -1, jnp.int32),
        incarnation=jnp.zeros((n,), jnp.int32),
        executed=jnp.zeros((n,), jnp.bool_),
        needs_exec=jnp.ones((n,), jnp.bool_),
        blocked_by=jnp.full((n,), -1, jnp.int32),
        frontier=jnp.asarray(0, jnp.int32),
        wave=jnp.asarray(0, jnp.int32),
        index=backend.build(jnp.full((n, w), NO_LOC, jnp.int32)),
        stat_execs=jnp.asarray(0, jnp.int32),
        stat_dep_aborts=jnp.asarray(0, jnp.int32),
        stat_val_aborts=jnp.asarray(0, jnp.int32),
        stat_wrote_new=jnp.asarray(0, jnp.int32),
    )


def _select_wave(state: EngineState, cfg: EngineConfig) -> tuple[jax.Array, jax.Array]:
    """Pick the ``window`` lowest-index eligible transactions.

    This is the BSP analogue of the paper's ``execution_idx`` counter: threads
    always claim the lowest READY_TO_EXECUTE transaction.  A txn blocked on a
    dependency is ineligible until its blocker has re-executed (paper:
    ``resume_dependencies``).
    """
    n = cfg.n_txns
    safe_blocker = jnp.clip(state.blocked_by, 0, n - 1)
    dep_resolved = (state.blocked_by < 0) | state.executed[safe_blocker]
    eligible = state.needs_exec & dep_resolved
    # First `window` eligible indices: nonzero(size=) is O(n) (cumsum+scatter)
    # vs the O(n log n) argsort it replaces (§Perf iteration 3).  Fill lanes
    # stay OUT-OF-BOUNDS (= n): XLA clips them on gather (garbage lanes are
    # masked) and drops them on scatter — keeping in-bounds indices unique.
    (active_ids,) = jnp.nonzero(eligible, size=cfg.window, fill_value=n)
    active_mask = active_ids < n
    return active_ids.astype(jnp.int32), active_mask


def _make_resolver(state: EngineState, cfg: EngineConfig):
    """Read-resolution closure for the current MV state (backend-selected).

    Every backend (sorted / dense / sharded) is consumed through the
    :class:`~repro.core.mv.base.MVBackend` protocol: the engine never touches
    index layout, only ``state.index`` as an opaque pytree.
    """
    return mv.make_backend(cfg).make_resolver(
        state.index, state.write_locs, state.estimate, state.incarnation)


def _execute_wave(state: EngineState, active_ids: jax.Array,
                  program: TxnProgram, params: Any, storage: jax.Array,
                  cfg: EngineConfig) -> ExecResult:
    """vmap the VM over the wave; reads resolve against the wave-start index.

    Dispatch over program representations (Python-DSL vs bytecode
    ``execute_spec`` objects) lives in the shared executor protocol
    (:func:`repro.core.vm.make_exec_one` via
    :func:`repro.core.executor.execute_txns`), which the Bohm/LiTM baselines
    use as well — one code path executes DSL and heterogeneous bytecode
    blocks under every engine.
    """
    resolver = _make_resolver(state, cfg)
    return executor.execute_txns(program, params, storage, cfg, resolver,
                                 state.write_vals, active_ids)


def _apply_results(state: EngineState, active_ids: jax.Array,
                   active_mask: jax.Array, res: ExecResult,
                   cfg: EngineConfig) -> EngineState:
    """Record finished incarnations (paper: MVMemory.record + finish_execution)
    and register dependencies for ESTIMATE-blocked executions
    (paper: add_dependency)."""
    success = active_mask & ~res.blocked
    blocked = active_mask & res.blocked

    old_wlocs = state.write_locs[active_ids]
    # wrote_new_location (paper L35): any live new loc absent from the old set.
    new_live = res.write_locs != NO_LOC
    in_old = (res.write_locs[:, :, None] == old_wlocs[:, None, :]).any(-1)
    wrote_new = (new_live & ~in_old).any(-1)

    sel = lambda m, a, b: jnp.where(m[:, None] if a.ndim == 2 else m, a, b)
    upd = lambda arr, new: arr.at[active_ids].set(
        sel(success, new, arr[active_ids]))

    state = state._replace(
        write_locs=upd(state.write_locs, res.write_locs),
        write_vals=upd(state.write_vals, res.write_vals),
        read_locs=upd(state.read_locs, res.read_locs),
        read_writer=upd(state.read_writer, res.read_writer),
        read_inc=upd(state.read_inc, res.read_inc),
        estimate=state.estimate.at[active_ids].set(
            jnp.where(success, False, state.estimate[active_ids])),
        incarnation=state.incarnation.at[active_ids].add(
            success.astype(jnp.int32)),
        executed=state.executed.at[active_ids].set(
            jnp.where(success, True, state.executed[active_ids])),
        needs_exec=state.needs_exec.at[active_ids].set(
            jnp.where(success, False, state.needs_exec[active_ids])),
        blocked_by=state.blocked_by.at[active_ids].set(
            jnp.where(blocked, res.blocker,
                      jnp.where(success, -1, state.blocked_by[active_ids]))),
        stat_execs=state.stat_execs + success.sum(dtype=jnp.int32),
        stat_dep_aborts=state.stat_dep_aborts + blocked.sum(dtype=jnp.int32),
        stat_wrote_new=state.stat_wrote_new
        + (success & wrote_new).sum(dtype=jnp.int32),
    )
    return state


def _read_set_valid(state: EngineState, cfg: EngineConfig, read_locs,
                    read_writer, read_inc, readers) -> jax.Array:
    """validate_read_set (paper L62-72), vectorized over rows."""
    resolver = _make_resolver(state, cfg)
    res = jax.vmap(jax.vmap(resolver))(read_locs, readers)
    empty = read_locs == NO_LOC
    was_storage = read_writer == STORAGE
    ok_storage = was_storage & ~res.found                       # L68
    ok_mv = (~was_storage) & res.found & ~res.is_estimate \
        & (res.writer == read_writer) & (res.inc == read_inc)   # L70
    read_ok = empty | jnp.where(was_storage, ok_storage, ok_mv)
    read_ok = read_ok & ~(res.is_estimate & ~empty)              # L67
    return read_ok.all(axis=-1)


def _validate_all(state: EngineState, cfg: EngineConfig) -> EngineState:
    """Validate executed txns against the fresh index (paper:
    validate_read_set + finish_validation).

    With ``validation_window == 0`` every executed txn is re-validated each
    wave (conservative BSP).  With ``vw > 0`` only the txns in
    [frontier, frontier + vw) are validated — the BSP analogue of the paper's
    ``validation_idx`` sweep: validation effort concentrates just above the
    commit frontier and moves up with it.  Safety is unchanged because the
    frontier only ever advances across txns validated in the current wave.
    """
    n, r = cfg.n_txns, cfg.max_reads
    vw = cfg.validation_window
    if vw <= 0 or vw >= n:
        readers = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None],
                                   (n, r))
        valid = _read_set_valid(state, cfg, state.read_locs,
                                state.read_writer, state.read_inc, readers)
        fail = state.executed & ~valid
        ok_for_commit = state.executed & ~fail
    else:
        start = jnp.minimum(state.frontier, n - vw)
        rows = start + jnp.arange(vw, dtype=jnp.int32)
        readers = jnp.broadcast_to(rows[:, None], (vw, r))
        valid_w = _read_set_valid(
            state, cfg,
            jax.lax.dynamic_slice_in_dim(state.read_locs, start, vw),
            jax.lax.dynamic_slice_in_dim(state.read_writer, start, vw),
            jax.lax.dynamic_slice_in_dim(state.read_inc, start, vw),
            readers)
        fail = jnp.zeros((n,), jnp.bool_).at[rows].set(~valid_w)
        fail = fail & state.executed
        # only txns validated THIS wave (or already committed) may commit
        in_window = jnp.zeros((n,), jnp.bool_).at[rows].set(True)
        below = jnp.arange(n, dtype=jnp.int32) < state.frontier
        ok_for_commit = state.executed & ~fail & (in_window | below)

    state = state._replace(
        estimate=state.estimate | fail,
        executed=state.executed & ~fail,
        needs_exec=state.needs_exec | fail,
        stat_val_aborts=state.stat_val_aborts + fail.sum(dtype=jnp.int32),
    )
    # Commit frontier: longest validated-executed prefix (monotone).
    prefix = jnp.cumprod(ok_for_commit.astype(jnp.int32))
    frontier = jnp.maximum(state.frontier, prefix.sum().astype(jnp.int32))
    return state._replace(frontier=frontier)


def _wave_step(state: EngineState, program: TxnProgram, params: Any,
               storage: jax.Array, cfg: EngineConfig) -> EngineState:
    active_ids, active_mask = _select_wave(state, cfg)
    res = _execute_wave(state, active_ids, program, params, storage, cfg)
    state = _apply_results(state, active_ids, active_mask, res, cfg)
    state = state._replace(
        index=mv.make_backend(cfg).build(state.write_locs))
    state = _validate_all(state, cfg)
    return state._replace(wave=state.wave + 1)


def _snapshot(state: EngineState, storage: jax.Array,
              cfg: EngineConfig) -> jax.Array:
    """MVMemory.snapshot over the engine's backend-selected resolver."""
    return executor.read_snapshot(_make_resolver(state, cfg),
                                  state.write_vals, storage, cfg)


def run_block(program: TxnProgram, params: Any, storage: jax.Array,
              cfg: EngineConfig) -> BlockResult:
    """Execute one block under Block-STM semantics. Jit-compatible."""
    state = _init_state(cfg)
    cap = jnp.asarray(cfg.waves_cap(), jnp.int32)

    def cond(s: EngineState):
        return (s.frontier < cfg.n_txns) & (s.wave < cap)

    def body(s: EngineState):
        return _wave_step(s, program, params, storage, cfg)

    state = jax.lax.while_loop(cond, body, state)
    return BlockResult(
        snapshot=_snapshot(state, storage, cfg),
        committed=state.frontier >= cfg.n_txns,
        waves=state.wave,
        execs=state.stat_execs,
        dep_aborts=state.stat_dep_aborts,
        val_aborts=state.stat_val_aborts,
        wrote_new=state.stat_wrote_new,
    )


def make_executor(program: TxnProgram, cfg: EngineConfig) -> Callable:
    """Jitted block executor: (params, storage) -> BlockResult."""
    @functools.partial(jax.jit, donate_argnums=())
    def run(params, storage):
        return run_block(program, params, storage, cfg)
    return run


def run_chain(program: TxnProgram, blocks_params: Any, storage: jax.Array,
              cfg: EngineConfig) -> tuple[jax.Array, BlockStats]:
    """Execute a CHAIN of blocks: each block's committed snapshot becomes the
    next block's storage (the blockchain validator loop; paper §1 "state is
    updated per block").  ``blocks_params`` leaves have a leading block axis.
    Jit-compatible: one compiled program executes the whole chain via scan.

    Returns ``(final_state, stats)`` where ``stats`` is a
    :class:`~repro.core.types.BlockStats` with one leading block axis per
    field — per-block counters come out typed, with no snapshot placeholder
    inflating the scan carry.
    """
    def step(st, params):
        res = run_block(program, params, st, cfg)
        return res.snapshot, res.stats()

    final_state, stats = jax.lax.scan(step, storage, blocks_params)
    return final_state, stats

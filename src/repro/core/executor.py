"""Unified executor protocol: one execution/snapshot path for every engine.

The paper's headline claim is *comparative* — Block-STM vs Bohm-style
deterministic re-execution vs LiTM-style batched STM on identical blocks
(§4.1).  For the comparison to be meaningful here, all engines must execute
transactions through the same VM dispatch and read committed state through
the same multi-version resolution.  This module is that shared layer:

* :func:`execute_txns`      — vmapped speculative execution of a set of txns
                              against an arbitrary resolver (the wave engine
                              passes its MV view; baselines pass a
                              committed-prefix view).  Dispatches through
                              :func:`repro.core.vm.make_exec_one`, so DSL and
                              bytecode/mixed blocks run everywhere.
* :func:`committed_resolver`— read resolution restricted to a boolean mask of
                              live (committed/executed) transactions: MVMemory
                              with final values only, which is exactly the
                              read view of Bohm rounds, LiTM rounds, and both
                              engines' final snapshots.
* :func:`read_snapshot`     — MVMemory.snapshot (paper L55-61) over any
                              resolver: highest live writer per location, else
                              pre-block storage.
* :func:`run_engine`        — name-indexed front-end over the four engines
                              (``sequential`` / ``blockstm`` / ``bohm`` /
                              ``litm``) used by the differential conformance
                              suite and the benchmark grid.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import mv
from repro.core.types import NO_LOC, EngineConfig
from repro.core.vm import TxnProgram, make_exec_one

#: Engine names accepted by :func:`run_engine`.
ENGINES = ("sequential", "blockstm", "bohm", "litm")


def execute_txns(program: TxnProgram, params: Any, storage: jax.Array,
                 cfg: EngineConfig, resolver, write_vals: jax.Array,
                 txn_ids: jax.Array | None = None):
    """vmap one speculative incarnation of each txn in ``txn_ids``.

    Reads resolve through ``resolver``; resolved MV hits gather their value
    from ``write_vals``, misses fall back to ``storage``.  Out-of-bounds ids
    (= n_txns fill lanes from the wave selection) produce garbage lanes that
    the caller masks.  ``txn_ids=None`` executes the whole block without
    gathering the params pytree (the baselines call this every round — the
    gather would be an identity copy of every array, code tensors included).

    ``txn_ids`` may be any length — under the dist engine each device calls
    this with its ``ceil(window/D)`` lane slice of the wave (padded with fill
    lanes), reading through the backend's routed resolver; the garbage the
    fill lanes produce is a pure function of the id, so every device's pad
    lanes compute identically and the post-gather slice stays deterministic.
    """
    def value_reader(res, loc):
        return mv.resolve_value(write_vals, storage, res, loc)

    exec_one = make_exec_one(program, cfg, resolver, value_reader)
    if txn_ids is None:
        return jax.vmap(exec_one)(jnp.arange(cfg.n_txns, dtype=jnp.int32),
                                  params)
    p_sel = jax.tree_util.tree_map(lambda a: a[txn_ids], params)
    return jax.vmap(exec_one)(txn_ids, p_sel)


def committed_resolver(write_locs: jax.Array, live: jax.Array,
                       incarnation: jax.Array, cfg: EngineConfig):
    """Resolver over the write sets of ``live`` transactions only.

    This is MVMemory restricted to final values — no ESTIMATEs, so reads
    never block.  Baseline rounds and snapshots both read through it, via
    whatever MV backend ``cfg.backend`` selects (the baselines honor the
    backend exactly like the wave engine does).  Under ``cfg.dist`` the
    backend builds each device's local region index and resolves through the
    gathered view — distribution rides the protocol, but the call must then
    execute inside the region mesh's shard_map (the dist engine's context).
    """
    backend = mv.make_backend(cfg)
    masked = jnp.where(live[:, None], write_locs, NO_LOC)
    no_estimates = jnp.zeros((cfg.n_txns,), jnp.bool_)
    return backend.make_resolver(backend.build(masked), masked, no_estimates,
                                 incarnation)


def read_snapshot(resolver, write_vals: jax.Array, storage: jax.Array,
                  cfg: EngineConfig) -> jax.Array:
    """MVMemory.snapshot (paper L55-61): read every location as txn ``n``."""
    reader = jnp.asarray(cfg.n_txns, jnp.int32)

    def read_final(loc):
        res = resolver(loc, reader)
        return mv.resolve_value(write_vals, storage, res, loc)

    return jax.vmap(read_final)(jnp.arange(cfg.n_locs, dtype=jnp.int32))


def run_engine(name: str, program: TxnProgram, params: Any,
               storage: jax.Array, cfg: EngineConfig, *,
               perfect_write_locs: jax.Array | None = None,
               mesh: Any = None):
    """Run one block under the named engine.

    Returns ``(snapshot, committed, stats)`` where ``stats`` is a small dict
    of engine-specific counters.  For ``bohm``, the oracle write-set pre-pass
    runs automatically unless ``perfect_write_locs`` is supplied (the paper
    grants Bohm the sets 'artificially'; so do we).

    ``mesh`` (a 1-D ``('regions',)`` mesh, see ``launch.mesh.make_mesh``)
    runs Block-STM multi-device: MV regions are placed across the mesh and
    the block executes under ``jax.shard_map`` (:mod:`repro.core.dist`),
    with the committed snapshot gathered back replicated.  The comparison
    baselines are single-device by construction (their loops are Python-
    level rounds), so ``mesh`` is rejected for them rather than silently
    ignored.
    """
    if (mesh is not None or cfg.dist) and name != "blockstm":
        # Also catches a caller-built dist config: the baselines would
        # otherwise construct the dist backend outside any shard_map and
        # die on an unbound 'regions' axis deep inside jax.
        raise NotImplementedError(
            f"mesh=/cfg.dist (multi-device execution) is a Block-STM "
            f"engine feature; engine {name!r} runs single-device")
    if mesh is not None:
        if cfg.backend != "sharded":
            raise ValueError(
                f"mesh= places the sharded backend's regions across "
                f"devices; cfg.backend={cfg.backend!r} would be silently "
                f"replaced — pass a backend='sharded' config")
        cfg = dataclasses.replace(cfg, dist=True, mesh=mesh)
    if name == "sequential":
        from repro.core.vm import run_sequential
        snap = run_sequential(program, params, storage, cfg.n_txns)
        return jnp.asarray(snap), jnp.asarray(True), {}
    if name == "blockstm":
        from repro.core.engine import run_block
        res = run_block(program, params, storage, cfg)
        return res.snapshot, res.committed, {
            "execs": res.execs, "waves": res.waves,
            "dep_aborts": res.dep_aborts, "val_aborts": res.val_aborts}
    from repro.core import baselines
    if name == "bohm":
        if perfect_write_locs is None:
            perfect_write_locs = baselines.perfect_write_sets(
                program, params, storage, cfg)
        res = baselines.run_bohm(program, params, storage, cfg,
                                 perfect_write_locs)
    elif name == "litm":
        res = baselines.run_litm(program, params, storage, cfg)
    else:
        raise ValueError(f"unknown engine {name!r}; expected one of {ENGINES}")
    return res.snapshot, res.committed, {
        "execs": res.execs, "rounds": res.rounds}

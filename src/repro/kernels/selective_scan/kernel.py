"""Pallas TPU kernel: Mamba-1 selective state-space scan (forward).

Recurrence (per batch b, channel d, state s):

    h_t = exp(dt_t[d] * A[d,s]) * h_{t-1} + dt_t[d] * B_t[s] * x_t[d]
    y_t[d] = Σ_s C_t[s] * h_t[d,s]

TPU mapping
-----------
* Grid ``(batch, D_blocks, T_blocks)`` — time is the sequential innermost
  dimension; the carried state ``h (block_d, d_state)`` is an f32 VMEM scratch
  persisting across T grid steps.
* Within a block the time loop is a ``fori_loop`` of VPU element-wise work on
  (block_d × d_state) tiles: with block_d=512, d_state=16 that is 8k lanes per
  step — full 8×128 VREG occupancy, no MXU needed (the scan is memory/VPU
  bound by construction).
* VMEM: x/dt tiles (block_t × block_d) f32 + B/C (block_t × d_state) +
  h (block_d × d_state): ≈1.2 MiB at (block_t=128, block_d=512, S=16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                 block_t: int):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                  # (block_d, S)

    def step(t, h):
        x_t = x_ref[0, t, :].astype(jnp.float32)        # (block_d,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)      # (block_d,)
        b_t = b_ref[0, t, :].astype(jnp.float32)        # (S,)
        c_t = c_ref[0, t, :].astype(jnp.float32)        # (S,)
        decay = jnp.exp(dt_t[:, None] * a)              # (block_d, S)
        h = decay * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)         # (block_d,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, block_t, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("block_t", "block_d", "interpret"))
def selective_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                   c: jax.Array, *, block_t: int = 128, block_d: int = 512,
                   interpret: bool = True) -> jax.Array:
    """x, dt: (B, T, D); a: (D, S); b, c: (B, T, S) -> y: (B, T, D)."""
    bsz, t, d = x.shape
    s = a.shape[1]
    block_t = min(block_t, t)
    block_d = min(block_d, d)
    pad_t = (-t) % block_t
    pad_d = (-d) % block_d
    xp = jnp.pad(x, ((0, 0), (0, pad_t), (0, pad_d)))
    dtp = jnp.pad(dt, ((0, 0), (0, pad_t), (0, pad_d)))
    ap = jnp.pad(a, ((0, pad_d), (0, 0)))
    bp = jnp.pad(b, ((0, 0), (0, pad_t), (0, 0)))
    cp = jnp.pad(c, ((0, 0), (0, pad_t), (0, 0)))
    pt, pd = xp.shape[1], xp.shape[2]
    grid = (bsz, pd // block_d, pt // block_t)
    y = pl.pallas_call(
        functools.partial(_scan_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda b_, db, tb: (b_, tb, db)),
            pl.BlockSpec((1, block_t, block_d), lambda b_, db, tb: (b_, tb, db)),
            pl.BlockSpec((block_d, s), lambda b_, db, tb: (db, 0)),
            pl.BlockSpec((1, block_t, s), lambda b_, db, tb: (b_, tb, 0)),
            pl.BlockSpec((1, block_t, s), lambda b_, db, tb: (b_, tb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_d),
                               lambda b_, db, tb: (b_, tb, db)),
        out_shape=jax.ShapeDtypeStruct((bsz, pt, pd), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, s), jnp.float32)],
        interpret=interpret,
    )(xp, dtp, ap, bp, cp)
    return y[:, :t, :d]

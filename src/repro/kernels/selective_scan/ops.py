"""Jitted public wrapper for the selective scan."""
import jax

from repro.kernels.selective_scan import kernel, ref


def selective_scan(x, dt, a, b, c, *, impl: str = "xla",
                   block_t: int = 128, block_d: int = 512, chunk: int = 64):
    """impl: 'xla' (chunked scan, production) | 'xla_naive' | 'pallas'."""
    if impl == "pallas":
        return kernel.selective_scan(
            x, dt, a, b, c, block_t=block_t, block_d=block_d,
            interpret=jax.default_backend() != "tpu")
    if impl == "xla_naive":
        return ref.selective_scan_ref(x, dt, a, b, c)
    return ref.selective_scan_chunked(x, dt, a, b, c, chunk=chunk)

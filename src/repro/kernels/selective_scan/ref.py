"""Pure-jnp oracle for the Mamba-1 selective scan (associative-scan form).

The recurrence h_t = g_t * h_{t-1} + u_t is a first-order linear scan, so it
admits the associative combine (g, u) ∘ (g', u') = (g·g', g'·u + u'); this is
also the production XLA path used by models/mamba.py (log-depth on TPU).
"""
import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, a, b, c):
    """x, dt: (B, T, D); a: (D, S); b, c: (B, T, S) -> y: (B, T, D)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    af, bf, cf = a.astype(jnp.float32), b.astype(jnp.float32), c.astype(jnp.float32)
    g = jnp.exp(dtf[..., None] * af[None, None])              # (B,T,D,S)
    u = (dtf * xf)[..., None] * bf[:, :, None, :]             # (B,T,D,S)

    def combine(p, q):
        (gp, up), (gq, uq) = p, q
        return gp * gq, gq * up + uq

    _, h = jax.lax.associative_scan(combine, (g, u), axis=1)
    y = jnp.einsum("btds,bts->btd", h, cf)
    return y.astype(x.dtype)


def selective_scan_chunked(x, dt, a, b, c, chunk: int = 64):
    """Two-level scan: sequential over time-chunks, associative within.

    The flat associative scan materializes (B, T, D, N) — at d_inner=8192,
    T=32k that is terabytes.  Chunking bounds live state memory to
    (B, chunk, D, N) transient + a (B, D, N) carry, which is the XLA
    production path (the Pallas kernel streams the same schedule in VMEM).
    """
    bsz, t, d = x.shape
    n = a.shape[1]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    def chunk_arrays(arr):
        return arr.reshape(bsz, nc, chunk, *arr.shape[2:]).swapaxes(0, 1)

    xs = (chunk_arrays(x.astype(jnp.float32)),
          chunk_arrays(dt.astype(jnp.float32)),
          chunk_arrays(b.astype(jnp.float32)),
          chunk_arrays(c.astype(jnp.float32)))
    af = a.astype(jnp.float32)

    def combine(p, q):
        (gp, up), (gq, uq) = p, q
        return gp * gq, gq * up + uq

    # checkpointed: backward recomputes the (B, chunk, D, N) scan states per
    # chunk instead of keeping every chunk's states alive simultaneously.
    @jax.checkpoint
    def per_chunk(h0, inp):
        xc, dtc, bc, cc = inp                          # (B, c, ...)
        g = jnp.exp(dtc[..., None] * af[None, None])   # (B,c,D,N)
        u = (dtc * xc)[..., None] * bc[:, :, None, :]
        gs, hs = jax.lax.associative_scan(combine, (g, u), axis=1)
        hs = hs + gs * h0[:, None]                     # fold in carry
        y = jnp.einsum("bcdn,bcn->bcd", hs, cc)
        return hs[:, -1], y

    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    _, ys = jax.lax.scan(per_chunk, h0, xs)
    y = ys.swapaxes(0, 1).reshape(bsz, nc * chunk, d)[:, :t]
    return y.astype(x.dtype)


def selective_scan_seq_ref(x, dt, a, b, c):
    """Step-by-step lax.scan reference (slow, maximally literal)."""
    bsz, t, d = x.shape
    s = a.shape[1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t[..., None] * a[None])            # (B,D,S)
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y_t

    h0 = jnp.zeros((bsz, d, s), jnp.float32)
    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1).astype(jnp.float32),
          c.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)

"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd dispatch wrapper) and ref.py (pure-jnp oracle):

* mv_resolve        — Block-STM dense multi-version read-resolution table
                      (tiny universes: the (n+1, L) last-writer cummax)
* mv_region_resolve — Block-STM sharded multi-version read resolution: the
                      batched per-region segment search (keys resident in
                      VMEM, queries streamed; gather-free compare-and-count),
                      wired into the engine via EngineConfig.resolver_impl
* flash_attention   — FlashAttention-2 forward w/ GQA + causal (train & decode)
* selective_scan    — Mamba-1 selective state-space scan
"""

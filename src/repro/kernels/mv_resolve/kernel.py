"""Pallas TPU kernel: multi-version read resolution table (dense backend).

Computes the inclusive running maximum along the transaction axis of the
write-mark matrix ``marks[(i, l)] = i if tx_i writes location l else -1`` —
the table from which every MVMemory read ``(loc, reader)`` resolves with one
gather (see ``repro.core.mv.dense.dense_last_writer``).

TPU mapping
-----------
* Grid ``(L_blocks, N_blocks)``: the location axis is embarrassingly parallel
  (outer, parallelisable); the txn axis is a sequential reduction (inner,
  ``arbitrary``) whose running maximum lives in a VMEM scratch that persists
  across the inner grid steps — the standard revisiting-accumulator pattern.
* In-block inclusive scan is a Hillis-Steele ladder of ``log2(block_n)``
  shift+max steps on the (block_n, block_l) VMEM tile: pure VPU work, 8-lane
  friendly, no MXU involvement.
* Block defaults (256, 512) i32 = 512 KiB/tile; with in/out + scratch the
  VMEM working set is ~1.2 MiB, well under the ~16 MiB/core budget, leaving
  room for double buffering of the streaming input.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cummax_block(x: jax.Array, block_n: int) -> jax.Array:
    """Inclusive cummax along axis 0 via log-step shift+max (static shapes)."""
    k = 1
    while k < block_n:
        shifted = jnp.pad(x, ((k, 0), (0, 0)), constant_values=-(2**31 - 1))[:-k]
        x = jnp.maximum(x, shifted)
        k *= 2
    return x


def _mv_resolve_kernel(marks_ref, out_ref, running_ref, *, block_n: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        running_ref[...] = jnp.full_like(running_ref, -(2**31 - 1))

    tile = marks_ref[...]
    inc = _cummax_block(tile, block_n)
    inc = jnp.maximum(inc, running_ref[...])     # fold in carry from prior blocks
    out_ref[...] = inc
    running_ref[...] = inc[-1:, :]


@functools.partial(jax.jit, static_argnames=("block_n", "block_l", "interpret"))
def mv_resolve_inclusive(marks: jax.Array, *, block_n: int = 256,
                         block_l: int = 512,
                         interpret: bool | None = None) -> jax.Array:
    """Inclusive running max of ``marks`` along axis 0 (txns), tiled on TPU.

    ``interpret=None`` auto-selects: compiled kernel on a TPU backend,
    interpreter elsewhere (the old unconditional ``interpret=True`` default
    silently ran the interpreter ON TPU as well).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, l = marks.shape
    block_n = min(block_n, max(n, 1))
    block_l = min(block_l, max(l, 1))
    pad_n = (-n) % block_n
    pad_l = (-l) % block_l
    x = jnp.pad(marks, ((0, pad_n), (0, pad_l)), constant_values=-(2**31 - 1))
    pn, plc = x.shape
    grid = (plc // block_l, pn // block_n)
    out = pl.pallas_call(
        functools.partial(_mv_resolve_kernel, block_n=block_n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, block_l), lambda lb, nb: (nb, lb))],
        out_specs=pl.BlockSpec((block_n, block_l), lambda lb, nb: (nb, lb)),
        out_shape=jax.ShapeDtypeStruct((pn, plc), marks.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_l), marks.dtype)],
        interpret=interpret,
    )(x)
    return out[:n, :l]

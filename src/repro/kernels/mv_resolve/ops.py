"""Jitted public wrapper for the mv_resolve kernel.

``impl`` switch (the ``flash_attention/ops.py`` convention):
* ``'pallas'`` — the Pallas kernel; compiled on TPU, interpret-mode elsewhere
  (``interpret=None`` auto-detects the backend; same kernel body and
  BlockSpec pipeline semantics either way, validated against ``ref.py`` in
  tests/test_kernels.py).
* ``'xla'``    — the pure-jnp reference (``lax.cummax``).
"""
import jax
import jax.numpy as jnp

from repro.kernels.mv_resolve import kernel, ref


def exclusive_cummax(marks: jax.Array, *, impl: str = "pallas",
                     block_n: int = 256, block_l: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """(n+1, L) exclusive last-writer table from (n, L) write marks."""
    if impl == "xla":
        return ref.exclusive_cummax_ref(marks)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}; expected 'pallas' or 'xla'")
    inc = kernel.mv_resolve_inclusive(marks, block_n=block_n, block_l=block_l,
                                      interpret=interpret)
    zero = jnp.full((1, marks.shape[1]), -1, dtype=marks.dtype)
    return jnp.concatenate([zero, inc], axis=0)

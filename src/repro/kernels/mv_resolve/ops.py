"""Jitted public wrapper for the mv_resolve kernel.

On TPU the Pallas kernel runs compiled (interpret=False); on CPU (this
container) it runs in interpret mode, which executes the same kernel body and
BlockSpec pipeline semantics in pure JAX — bit-identical results, validated
against ``ref.py`` in tests/test_kernels.py.
"""
import jax
import jax.numpy as jnp

from repro.kernels.mv_resolve import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def exclusive_cummax(marks: jax.Array, *, block_n: int = 256,
                     block_l: int = 512, force_ref: bool = False) -> jax.Array:
    """(n+1, L) exclusive last-writer table from (n, L) write marks."""
    if force_ref:
        return ref.exclusive_cummax_ref(marks)
    inc = kernel.mv_resolve_inclusive(marks, block_n=block_n, block_l=block_l,
                                      interpret=not _on_tpu())
    zero = jnp.full((1, marks.shape[1]), -1, dtype=marks.dtype)
    return jnp.concatenate([zero, inc], axis=0)

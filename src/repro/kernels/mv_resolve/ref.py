"""Pure-jnp oracle for the mv_resolve kernel."""
import jax
import jax.numpy as jnp


def mv_resolve_inclusive_ref(marks: jax.Array) -> jax.Array:
    """Inclusive running max of write marks along the txn axis."""
    return jax.lax.cummax(marks, axis=0)


def exclusive_cummax_ref(marks: jax.Array) -> jax.Array:
    """(n+1, L) exclusive table: row j = max of rows < j (row 0 = -1)."""
    zero = jnp.full((1, marks.shape[1]), -1, dtype=marks.dtype)
    return jnp.concatenate([zero, jax.lax.cummax(marks, axis=0)], axis=0)

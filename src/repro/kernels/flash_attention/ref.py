"""Pure-jnp oracle for flash attention (GQA + causal, f32 math) +
the chunked XLA production path.

``attention_ref`` materializes the full (B, H, Sq, Skv) score tensor — exact
but O(S²) memory; it is the test oracle and fine for short sequences.
``attention_chunked_ref`` is the XLA path used at 32k+ sequence lengths: a
``lax.scan`` over query chunks bounds live score memory to
(B, H, chunk, Skv) while remaining numerically identical (full-row softmax
per chunk, not online).
"""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). Matches kernel semantics."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if scale is None:
        scale = d ** -0.5
    group = hq // hkv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kf)
    if causal:
        q_pos = jnp.arange(sq)[:, None] + (skv - sq)
        k_pos = jnp.arange(skv)[None, :]
        s = jnp.where(q_pos >= k_pos, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def attention_chunked_ref(q, k, v, *, causal: bool = True,
                          scale: float | None = None, chunk: int = 1024,
                          expand_kv: bool = True):
    """Query-chunked attention; same semantics as attention_ref."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if scale is None:
        scale = d ** -0.5
    chunk = min(chunk, sq)
    if sq % chunk:
        return attention_ref(q, k, v, causal=causal, scale=scale)
    group = hq // hkv
    nq = sq // chunk
    # GQA: expand KV to the full query-head axis.  A (hkv, group) split of
    # the head axis is NOT expressible as a sharding when tp does not divide
    # hkv — the SPMD partitioner replicates the whole score tensor ("
    # involuntary full rematerialization").  With the repeat, every einsum
    # keeps the head axis, each model shard materializes only its own
    # hq/tp KV-head copies, and scores stay head-sharded (§Perf cell-2 fix).
    qc = (q.astype(jnp.float32) * scale).reshape(b, hq, nq, chunk, d)
    qc = qc.transpose(2, 0, 1, 3, 4)                    # (nq, B, H, c, D)
    # expand_kv=False (sequence-parallel attention): heads are replicated
    # anyway, so the un-expanded grouped einsum path is cheaper there.
    do_expand = group > 1 and expand_kv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32) if do_expand \
        else k.astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32) if do_expand \
        else v.astype(jnp.float32)
    if not do_expand and group > 1:
        return _chunked_grouped(q, kf, vf, scale=scale, causal=causal,
                                chunk=chunk, group=group)
    k_pos = jnp.arange(skv)
    offset = skv - sq

    # checkpointed: backward recomputes the (c, Skv) score/softmax tile per
    # chunk instead of saving O(S^2) softmax weights across all chunks.
    @jax.checkpoint
    def chunk_attn(i, qi, kf, vf):
        s = jnp.einsum("bhqd,bhkd->bhqk", qi, kf)
        if causal:
            q_pos = i * chunk + jnp.arange(chunk) + offset
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, -jnp.inf)
        p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vf)

    def one_chunk(_, args):
        i, qi = args                                    # qi: (B,H,c,D)
        return None, chunk_attn(i, qi, kf, vf)

    _, outs = jax.lax.scan(one_chunk, None, (jnp.arange(nq), qc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, d)
    return out.astype(q.dtype)


def _chunked_grouped(q, kf, vf, *, scale, causal, chunk, group):
    """Un-expanded GQA path for replicated-head (sequence-parallel) attention."""
    import jax
    b, hq, sq, d = q.shape
    hkv, skv = kf.shape[1], kf.shape[2]
    nq = sq // chunk
    qc = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, nq, chunk, d)
    qc = qc.transpose(3, 0, 1, 2, 4, 5)
    k_pos = jnp.arange(skv)
    offset = skv - sq

    @jax.checkpoint
    def chunk_attn(i, qi):
        s = jnp.einsum("bgmqd,bgkd->bgmqk", qi, kf)
        if causal:
            q_pos = i * chunk + jnp.arange(chunk) + offset
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, -jnp.inf)
        p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        return jnp.einsum("bgmqk,bgkd->bgmqd", p, vf)

    def one_chunk(_, args):
        i, qi = args
        return None, chunk_attn(i, qi)

    _, outs = jax.lax.scan(one_chunk, None, (jnp.arange(nq), qc))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, sq, d)
    return out.astype(q.dtype)

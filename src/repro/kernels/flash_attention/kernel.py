"""Pallas TPU kernel: FlashAttention-2 forward with GQA and causal masking.

TPU mapping
-----------
* Grid ``(batch, q_heads, Sq_blocks, Skv_blocks)`` — the KV axis is the
  innermost, sequential dimension; the online-softmax running statistics
  (row-max ``m``, row-sum ``l``) and the f32 output accumulator live in VMEM
  scratch that persists across KV grid steps.
* GQA is free in the BlockSpec index map: query head ``h`` reads KV head
  ``h // (Hq // Hkv)`` — no KV replication in HBM.
* ``block_q × d`` and ``block_k × d`` tiles are MXU-aligned for d ∈
  {64, 128, 256} (multiples of 128 lanes; bf16 inputs, f32 accumulation via
  ``preferred_element_type``).
* Default blocks (128, 128) with d=128: q/k/v tiles 64 KiB (bf16 32 KiB),
  acc + stats ~68 KiB f32 — comfortably double-bufferable in ~16 MiB VMEM.
* Causal decode is the same kernel with ``Sq=1`` and query-position offset
  ``Skv - Sq`` (KV-cache attention); fully-masked KV blocks are skipped with
  ``pl.when`` so decode over a 500k cache does no wasted MXU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               n_kb: int, q_offset: int, kv_len: int):
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = pl.program_id(2) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # Skip KV blocks that are entirely in the causal shadow or padding.
    first_q = pl.program_id(2) * block_q + q_offset
    last_q = first_q + block_q - 1
    block_live = (kb * block_k <= last_q) if causal else True
    block_live = jnp.logical_and(block_live, kb * block_k < kv_len)

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < kv_len
        if causal:
            mask = mask & (q_pos >= k_pos)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        out_ref[0, 0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_qb = qp.shape[2] // block_q
    n_kb = kp.shape[2] // block_k
    # Decode/cache attention: query row i sits at absolute position
    # (Skv - Sq + i) so a single-row query attends to the whole cache.
    q_offset = skv - sq

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, n_kb=n_kb,
                          q_offset=q_offset, kv_len=skv),
        grid=(b, hq, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, qb, kb: (b_, h, qb, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qb, kb, g=group: (b_, h // g, kb, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qb, kb, g=group: (b_, h // g, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qb, kb: (b_, h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :sq, :]

"""Jitted public wrapper for flash attention.

Model code calls :func:`attention`, which dispatches to:
* the Pallas kernel (compiled on TPU, interpret-mode on CPU), or
* the pure-XLA reference — used for the multi-pod dry-run lowering so the
  compiled HLO (and its cost analysis) reflects the XLA production path.
"""
import jax

from repro.kernels.flash_attention import kernel, ref


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              impl: str = "xla", block_q: int = 128, block_k: int = 128,
              chunk: int = 1024, expand_kv: bool = True):
    """impl: 'xla' (query-chunked, production) | 'xla_naive' | 'pallas'."""
    if impl == "pallas":
        return kernel.flash_attention(
            q, k, v, causal=causal, scale=scale, block_q=block_q,
            block_k=block_k, interpret=jax.default_backend() != "tpu")
    if impl == "xla_naive":
        return ref.attention_ref(q, k, v, causal=causal, scale=scale)
    return ref.attention_chunked_ref(q, k, v, causal=causal, scale=scale,
                                     chunk=chunk, expand_kv=expand_kv)

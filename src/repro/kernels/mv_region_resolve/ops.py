"""Public wrapper for the region-resolve kernel + the resolver batching hook.

Two entry points:

* :func:`region_searchsorted` — explicit batched API with an ``impl=`` switch
  (the ``flash_attention/ops.py`` convention): ``'xla'`` is the hand-rolled
  :func:`~repro.core.mv.sharded.segment_searchsorted` bisection under
  ``vmap`` (production CPU path, and the kernel's parity reference),
  ``'pallas'`` the TPU kernel (interpret-mode off-TPU).
* :func:`batchable_segment_searchsorted` — what
  ``ShardedBackend.make_resolver(...)`` uses when
  ``EngineConfig.resolver_impl == 'pallas'``.  The MVBackend resolver
  protocol is *scalar* (the engine vmaps it over wave reads, validation rows,
  and the snapshot), so the kernel is wired in through
  :func:`jax.custom_batching.custom_vmap`: scalar calls keep the XLA
  bisection, while a vmapped call rewrites into ONE kernel launch over the
  whole batch.  The engine flattens its (rows, R) validation vmap to a single
  level (see ``engine._read_set_valid``) so the kernel always sees a flat
  query batch.  ``impl`` selection stays trace-time static — switching it
  never recompiles per contract mix, only per config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import custom_batching

from repro.core.mv.sharded import segment_searchsorted
from repro.kernels.mv_region_resolve import kernel


def region_searchsorted(keys: jax.Array, lo: jax.Array, hi: jax.Array,
                        qs: jax.Array, *, impl: str = "xla",
                        block_q: int = 256,
                        interpret: bool | None = None) -> jax.Array:
    """Batched ``lo[i] + searchsorted(keys[lo[i]:hi[i]], qs[i], 'left')``.

    impl: 'xla' (vmapped scalar bisection, production off-TPU) | 'pallas'.
    """
    if impl == "pallas":
        return kernel.segment_searchsorted_pallas(
            keys, lo, hi, qs, block_q=block_q, interpret=interpret)
    if impl == "xla":
        return jax.vmap(
            lambda l, h, q: segment_searchsorted(keys, l, h, q))(lo, hi, qs)
    raise ValueError(f"unknown impl {impl!r}; expected 'xla' or 'pallas'")


@custom_batching.custom_vmap
def batchable_segment_searchsorted(keys: jax.Array, lo: jax.Array,
                                   hi: jax.Array, q: jax.Array) -> jax.Array:
    """Scalar segment search whose vmap IS the Pallas kernel (see above)."""
    return segment_searchsorted(keys, lo, hi, q)


@batchable_segment_searchsorted.def_vmap
def _batch_rule(axis_size, in_batched, keys, lo, hi, qs):
    keys_b, lo_b, hi_b, qs_b = in_batched
    if keys_b:
        # Index itself batched (not an engine path): fall back to bisection.
        out = jax.vmap(segment_searchsorted,
                       in_axes=(0, 0 if lo_b else None, 0 if hi_b else None,
                                0 if qs_b else None))(keys, lo, hi, qs)
        return out, True
    lo = lo if lo_b else jnp.broadcast_to(lo, (axis_size,))
    hi = hi if hi_b else jnp.broadcast_to(hi, (axis_size,))
    qs = qs if qs_b else jnp.broadcast_to(qs, (axis_size,))
    return kernel.segment_searchsorted_pallas(keys, lo, hi, qs), True

"""Pure-jnp oracle for the region-resolve kernel (tests only: slices each
query's segment via dynamic masking, which the production paths avoid)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_KEY_MAX = jnp.iinfo(jnp.int32).max


def segment_searchsorted_ref(keys: jax.Array, lo: jax.Array, hi: jax.Array,
                             qs: jax.Array) -> jax.Array:
    """``lo[i] + searchsorted(keys[lo[i]:hi[i]], qs[i], 'left')`` per query."""
    def one(l, h, q):
        cols = jnp.arange(keys.shape[0], dtype=jnp.int32)
        in_seg = (cols >= l) & (cols < h)
        return l + jnp.sum(in_seg & (keys < q), dtype=jnp.int32)

    return jax.vmap(one)(lo, hi, qs)

"""Region-resolve kernel: batched row binary search for the sharded MV backend.

``kernel.py`` — Pallas TPU kernel (interpret-mode off-TPU), ``ref.py`` — pure
jnp oracle, ``ops.py`` — public dispatch (``impl='xla' | 'pallas'``) plus the
``custom_vmap`` wiring that lets the scalar resolver protocol batch into the
kernel.  See ``kernel.py`` for the TPU mapping.
"""

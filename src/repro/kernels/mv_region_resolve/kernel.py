"""Pallas TPU kernel: batched segment searchsorted (sharded MV resolve).

The sharded MV backend answers a read by binary-searching ONE segment
``keys[lo:hi]`` of its CSR-flat key list (``cap = n_txns*max_writes``
entries), bounds chosen per query from the region offsets.  The XLA path
(:func:`repro.core.mv.sharded.segment_searchsorted`) lowers, under ``vmap``,
to one scalar gather per bisection step — O(log cap) *serialized* gathers
per lane, a poor fit for the TPU's VPU, which has no vector-gather unit.

TPU mapping
-----------
* The whole key list is staged in VMEM once (``cap`` int32; the engine's
  real shapes — cap = n*W ≈ 1-32K — are far under the ~16 MiB budget;
  :func:`segment_searchsorted_pallas` asserts it) and REUSED across every
  grid step: queries stream, keys stay resident.
* Grid over query tiles ``(block_q,)``.  Per tile the kernel runs one
  compare-and-count pass: for a sorted segment,
  ``searchsorted_left(keys[lo:hi], q) == Σ_c [lo <= c < hi][keys[c] < q]``,
  so the whole answer is a broadcast compare of the resident keys against
  the lane's ``(q, lo, hi)`` plus a row-sum — pure 8×128 VPU work, no
  gather, no MXU.  This trades the un-vectorizable O(log cap) per-lane
  gather chain for O(cap) per-lane VPU throughput — the standard TPU
  exchange, and the reason the kernel wants the CSR layout (one flat pass)
  rather than the old (S, cap) row matrix (S passes).

Padding contract: dead key slots are +inf (``2^31-1``) and live queries are
strictly below it (the shard-local key bound leaves ``n_txns`` of headroom),
so column padding with +inf never perturbs a count; query-tile padding lanes
carry ``lo = hi = 0`` and are sliced off by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_KEY_MAX = jnp.iinfo(jnp.int32).max
_VMEM_KEY_BYTES = 8 * 2**20   # keys stay resident: keep them ≤ half of VMEM


def _segment_search_kernel(keys_ref, lo_ref, hi_ref, qs_ref, out_ref):
    keys = keys_ref[0, :]                       # (cap,) resident in VMEM
    lo = lo_ref[0, :]                           # (block_q,)
    hi = hi_ref[0, :]
    qs = qs_ref[0, :]
    col = jax.lax.broadcasted_iota(jnp.int32, (lo.shape[0], keys.shape[0]), 1)
    in_seg = (col >= lo[:, None]) & (col < hi[:, None])
    hit = in_seg & (keys[None, :] < qs[:, None])
    out_ref[0, :] = lo + jnp.sum(hit.astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def segment_searchsorted_pallas(keys: jax.Array, lo: jax.Array,
                                hi: jax.Array, qs: jax.Array, *,
                                block_q: int = 256,
                                interpret: bool | None = None) -> jax.Array:
    """``lo[i] + searchsorted(keys[lo[i]:hi[i]], qs[i], 'left')`` per query.

    ``keys``: (cap,) int32, ascending within every [lo, hi) segment queried.
    ``lo``/``hi``/``qs``: (Q,) int32.  ``interpret=None`` auto-selects:
    compiled on TPU, interpreter elsewhere (bit-identical semantics).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    (cap,) = keys.shape
    if cap * 4 > _VMEM_KEY_BYTES:
        raise ValueError(
            f"region keys ({cap} i32 = {cap * 4} bytes) exceed the "
            f"{_VMEM_KEY_BYTES}-byte VMEM residency budget; shrink the "
            f"block (n_txns*max_writes) or use resolver_impl='xla'")
    (q_n,) = qs.shape
    # Lane-align the resident key list and the query tiles.
    keys_p = jnp.pad(keys, (0, (-cap) % 128),
                     constant_values=_KEY_MAX)[None, :]
    block_q = max(128, min(block_q, -(-q_n // 128) * 128))
    pad_q = (-q_n) % block_q
    lo_p = jnp.pad(lo, (0, pad_q)).reshape(-1, block_q)
    hi_p = jnp.pad(hi, (0, pad_q)).reshape(-1, block_q)
    qs_p = jnp.pad(qs, (0, pad_q)).reshape(-1, block_q)
    grid = (qs_p.shape[0],)
    out = pl.pallas_call(
        _segment_search_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(keys_p.shape, lambda i: (0, 0)),
            pl.BlockSpec((1, block_q), lambda i: (i, 0)),
            pl.BlockSpec((1, block_q), lambda i: (i, 0)),
            pl.BlockSpec((1, block_q), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(qs_p.shape, jnp.int32),
        interpret=interpret,
    )(keys_p, lo_p, hi_p, qs_p)
    return out.reshape(-1)[:q_n]

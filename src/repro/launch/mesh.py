"""Mesh construction.

Every builder here is a FUNCTION so importing this module never touches jax
device state — callers (tests, examples, ``core/dist``) construct meshes
lazily, at call time.  Production target: TPU v5e, 256 chips/pod, 16x16
(data, model); multi-pod doubles with a leading 'pod' axis (data parallelism
across pods — the lowest-bandwidth dimension carries only gradient
all-reduces).
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(axis_names="data", shape=None) -> jax.sharding.Mesh:
    """Generic mesh over the actually-available devices (tests/examples).

    ``axis_names`` is one axis name (str) or a tuple of names; ``shape`` gives
    the per-axis sizes, where a single ``-1`` absorbs all remaining devices
    (the default for a 1-D mesh is ``(-1,)`` — one axis over everything).
    The first ``prod(shape)`` devices are used, so submeshes of the same
    process nest deterministically (``make_mesh('regions', (2,))`` is a prefix
    of ``make_mesh('regions', (8,))``).  Raises if more devices are requested
    than exist.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    devices = jax.devices()
    if shape is None:
        if len(axis_names) != 1:
            raise ValueError(f"shape is required for a multi-axis mesh "
                             f"(axis_names={axis_names})")
        shape = (-1,)
    shape = tuple(int(s) for s in shape)
    if len(shape) != len(axis_names):
        raise ValueError(f"shape {shape} does not match axes {axis_names}")
    if shape.count(-1) > 1:
        raise ValueError(f"at most one -1 axis allowed, got {shape}")
    if -1 in shape:
        known = math.prod(s for s in shape if s != -1)
        if known > len(devices) or len(devices) % known:
            # Silently filling a prefix would run on a fraction of the
            # hardware; a non-dividing axis is a misconfiguration (the
            # behavior jax.make_mesh had before this helper).
            raise ValueError(
                f"cannot fill the -1 axis: {len(devices)} devices do not "
                f"divide by the fixed axes {dict(zip(axis_names, shape))}")
        shape = tuple(len(devices) // known if s == -1 else s for s in shape)
    total = math.prod(shape)
    if total > len(devices):
        raise ValueError(f"mesh {dict(zip(axis_names, shape))} needs {total} "
                         f"devices; only {len(devices)} available")
    return jax.sharding.Mesh(
        np.asarray(devices[:total]).reshape(shape), axis_names)


def make_host_mesh(model_axis: int = 1):
    """Tiny (data, model) mesh over the available devices (tests/examples)."""
    return make_mesh(("data", "model"), (-1, model_axis))

"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state.  Production target: TPU v5e, 256 chips/pod, 16x16 (data, model);
multi-pod doubles with a leading 'pod' axis (data parallelism across pods —
the lowest-bandwidth dimension carries only gradient all-reduces).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over the actually-available devices (tests/examples)."""
    n = len(jax.devices())
    data = max(1, n // model_axis)
    return jax.make_mesh((data, model_axis), ("data", "model"))

"""End-to-end training driver with checkpoint/restart + fault tolerance.

Runs on whatever devices exist (laptop CPU through 512-chip pods): the mesh
is built over available devices, the data stream is deterministic and
resumable, checkpoints are async + atomic, preemption (SIGTERM) triggers a
final checkpoint, and a straggler monitor tracks step-time anomalies.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
      --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import SHAPES_BY_NAME, get_arch, reduced_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticLMStream
from repro.distributed import meshctx
from repro.distributed.fault_tolerance import (PreemptionHandler,
                                               StragglerMonitor)
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.runtime import steps as RT


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg, n_layers=args.layers, d_model=args.d_model)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 1),
                                state_dtype=cfg.opt_state_dtype)
    mesh = make_host_mesh(model_axis=args.model_axis)
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16

    preempt = PreemptionHandler()
    monitor = StragglerMonitor()
    manager = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    with meshctx.use_mesh(mesh):
        state = RT.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg, dtype)
        stream = SyntheticLMStream(cfg, args.batch, args.seq, seed=0)
        start = 0
        if manager and manager.latest_step() is not None:
            state, meta = manager.restore(state)
            start = meta["step"]
            stream.state.step = meta["extra"].get("data_step", start)
            print(f"[restore] resumed from step {start}")
        step_fn = RT.jit_train_step(cfg, shape, mesh, opt_cfg,
                                    microbatches=cfg.train_microbatches
                                    if not args.reduced else 1)

        t_start = time.time()
        for step in range(start, args.steps):
            monitor.start_step()
            batch = stream.next_batch()
            state, metrics = step_fn(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                m = monitor.end_step(step)
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"dt {m['step_time_s']*1e3:.0f}ms"
                      + (" [straggling]" if m["straggling"] else ""))
            else:
                monitor.end_step(step)
            if manager and (step + 1) % args.ckpt_every == 0:
                manager.save(step + 1, state,
                             extra={"data_step": stream.state.step})
            if preempt.preempted:
                print(f"[preempt] SIGTERM at step {step}; checkpointing")
                if manager:
                    manager.save(step + 1, state,
                                 extra={"data_step": stream.state.step},
                                 blocking=True)
                return 0
        if manager:
            manager.save(args.steps, state,
                         extra={"data_step": stream.state.step},
                         blocking=True)
        dt = time.time() - t_start
        tok = (args.steps - start) * args.batch * args.seq
        print(f"done: {args.steps - start} steps, {tok/dt:.0f} tok/s, "
              f"straggler flags: {monitor.flagged}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

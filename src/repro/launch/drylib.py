"""Dry-run cell logic: lower + compile one (arch × shape × mesh) and extract
memory / cost / collective statistics.

Shared by launch/dryrun.py (production 512-device meshes) and the tests
(small host meshes).  No real allocation ever happens: all inputs are
``ShapeDtypeStruct`` trees and only ``.lower().compile()`` is invoked.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES_BY_NAME, get_arch
from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import meshctx
from repro.launch import hlo_analysis
from repro.models import model as MDL
from repro.optim import adamw
from repro.runtime import steps as RT

# --- hardware constants (TPU v5e) -----------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-axis aggregate per chip)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6·N_active·tokens (train) or 2·N_active·batch (one decode step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str                       # ok | skipped | failed
    note: str = ""
    n_devices: int = 0
    # trip-count-aware, per-device-per-step (hlo_analysis walker):
    flops_dev: float = 0.0
    bytes_dev_hlo: float = 0.0           # CPU-lowering HLO bytes (conservative)
    bytes_dev: float = 0.0               # analytic TPU HBM model (launch/analytic)
    bytes_breakdown: Optional[dict] = None
    collectives: Optional[dict] = None   # per-device link bytes by op
    # raw cost_analysis (counts while bodies once; kept for reference):
    xla_flops_raw: float = 0.0
    xla_bytes_raw: float = 0.0
    memory: Optional[dict] = None        # per-device, from memory_analysis
    model_flops: float = 0.0             # 6·N·D (train) / 2·N·B (decode), global
    lower_s: float = 0.0
    compile_s: float = 0.0
    params: float = 0.0
    active_params: float = 0.0

    def roofline(self) -> dict:
        n = max(self.n_devices, 1)
        t_compute = self.flops_dev / PEAK_FLOPS
        t_memory = self.bytes_dev / HBM_BW
        coll = (self.collectives or {}).get("collective_bytes", 0.0)
        t_coll = coll / ICI_BW          # per-chip link bytes
        terms = {"compute_s": t_compute, "memory_s": t_memory,
                 "collective_s": t_coll}
        bound = max(terms, key=terms.get)
        model_dev = self.model_flops / n
        useful = model_dev / self.flops_dev if self.flops_dev else 0.0
        t_ideal = model_dev / PEAK_FLOPS
        return {**terms, "bound": bound.replace("_s", ""),
                "useful_flops_ratio": useful,
                "roofline_fraction":
                    t_ideal / max(max(terms.values()), 1e-30)}


def _prefill_step(cfg: ArchConfig, impl: str = "xla"):
    """Prefill lowering: forward to hidden states, unembed ONLY the last
    position (materializing (B, S, V) logits would cost ~17 GiB/device at
    32k x 256k-vocab)."""
    def step(params, batch):
        hidden, _ = MDL.train_hidden(params, batch, cfg, impl=impl)
        from repro.models import layers as L
        logits = L.unembed(params["embed"], hidden[:, -1:], cfg)
        return jnp.argmax(logits[:, 0], axis=-1)
    return step


def run_cell(arch_name: str, shape_name: str, mesh,
             mesh_label: str) -> CellResult:
    cfg = get_arch(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    res = CellResult(arch=arch_name, shape=shape_name, mesh=mesh_label,
                     status="ok", n_devices=mesh.devices.size,
                     params=float(cfg.param_count()),
                     active_params=float(cfg.active_param_count()))

    if shape.name == "long_500k" and not cfg.supports_long:
        res.status, res.note = "skipped", \
            "full quadratic attention; sub-quadratic mixing required " \
            "(DESIGN.md §6)"
        return res

    opt_cfg = adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype)
    with meshctx.use_mesh(mesh):
        t0 = time.time()
        if shape.kind == "train":
            fn = RT.jit_train_step(cfg, shape, mesh, opt_cfg,
                                   microbatches=cfg.train_microbatches)
            state = RT.train_state_struct(cfg, opt_cfg, jnp.bfloat16)
            batch = MDL.batch_struct(cfg, shape, jnp.bfloat16)
            lowered = fn.lower(state, batch)
        elif shape.kind == "prefill":
            sspec = meshctx.tree_shardings(MDL.param_specs(cfg), mesh)
            bspec = meshctx.tree_shardings(MDL.batch_specs(cfg, shape), mesh)
            fn = jax.jit(_prefill_step(cfg), in_shardings=(sspec, bspec))
            params = jax.eval_shape(
                lambda: MDL.init_params(jax.random.PRNGKey(0), cfg,
                                        jnp.bfloat16))
            batch = MDL.batch_struct(cfg, shape, jnp.bfloat16)
            lowered = fn.lower(params, batch)
        else:  # decode
            fn = RT.jit_serve_step(cfg, shape, mesh)
            params = jax.eval_shape(
                lambda: MDL.init_params(jax.random.PRNGKey(0), cfg,
                                        jnp.bfloat16))
            cache = RT.cache_struct(cfg, shape.global_batch, shape.seq_len,
                                    jnp.bfloat16)
            toks = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            lowered = fn.lower(params, cache, toks)
        res.lower_s = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        res.compile_s = time.time() - t0

        cost = compiled.cost_analysis() or {}
        res.xla_flops_raw = float(cost.get("flops", 0.0))
        res.xla_bytes_raw = float(cost.get("bytes accessed", 0.0))
        mem = compiled.memory_analysis()
        if mem is not None:
            res.memory = {
                k: float(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes")
                if hasattr(mem, k)
            }
        agg = hlo_analysis.aggregate(compiled.as_text())
        res.flops_dev = agg["flops"]
        res.bytes_dev_hlo = agg["bytes"]
        res.collectives = {k: v for k, v in agg.items()
                           if k not in ("flops", "bytes", "entry")}
        from repro.launch import analytic
        res.bytes_breakdown = analytic.bytes_model(cfg, shape,
                                                   mesh.devices.size)
        res.bytes_dev = res.bytes_breakdown["total"]
        res.model_flops = model_flops(cfg, shape)
    return res


def save_results(results: list, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    existing = {}
    if os.path.exists(path):
        with open(path) as f:
            for r in json.load(f):
                existing[(r["arch"], r["shape"], r["mesh"])] = r
    for r in results:
        d = dataclasses.asdict(r)
        if r.status == "ok":
            d["roofline"] = r.roofline()
        existing[(r.arch, r.shape, r.mesh)] = d
    with open(path, "w") as f:
        json.dump(list(existing.values()), f, indent=1)

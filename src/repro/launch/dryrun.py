import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run driver.

Proves the distribution config is coherent without hardware: for every
(architecture × input shape) the production train/serve step is
``.lower().compile()``d against the 16x16 single-pod mesh AND the 2x16x16
multi-pod mesh, printing memory and cost analysis and recording roofline
inputs to JSON (``drylib.roofline`` terms; per-phase accounting for the
engine suites lives in ``repro.obs.cost``).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single --out benchmarks/results/dryrun.json
"""
import argparse
import json
import sys
import traceback

import jax  # noqa: E402  (must come after XLA_FLAGS is set)

from repro.configs import ARCHS, SHAPES  # noqa: E402
from repro.launch import drylib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch name, comma list, or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name, comma list, or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    args = ap.parse_args(argv)

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else args.shape.split(",")
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("2pod_2x16x16", make_production_mesh(multi_pod=True)))

    results, failed = [], 0
    for mesh_label, mesh in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    r = drylib.run_cell(arch, shape, mesh, mesh_label)
                except Exception as e:  # a failure here is a sharding bug
                    traceback.print_exc()
                    r = drylib.CellResult(arch=arch, shape=shape,
                                          mesh=mesh_label, status="failed",
                                          note=f"{type(e).__name__}: {e}")
                    failed += 1
                results.append(r)
                tag = f"[{mesh_label}] {arch} x {shape}"
                if r.status == "ok":
                    rf = r.roofline()
                    mem = (r.memory or {})
                    print(f"{tag}: OK flops/dev={r.flops_dev:.3e} "
                          f"bytes/dev={r.bytes_dev:.3e} "
                          f"coll/dev={r.collectives['collective_bytes']:.3e} "
                          f"bound={rf['bound']} "
                          f"rf={rf['roofline_fraction']:.3f} "
                          f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                          f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                          f"compile={r.compile_s:.1f}s")
                else:
                    print(f"{tag}: {r.status.upper()} {r.note}")
                drylib.save_results([r], args.out)
    print(f"\n{len(results)} cells, {failed} failed -> {args.out}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())

"""Production mesh, multi-pod dry-run, train/serve drivers, HLO analysis."""

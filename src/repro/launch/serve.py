"""Serving driver: Block-STM transactional admission + batched decode.

The two halves of the framework meet here: each serving round runs

  1. an ADMISSION BLOCK — a block of request transactions (allocate KV pages
     from a shared free-list, charge tenant quotas) executed in parallel by
     the Block-STM engine, deterministically equivalent to sequential
     admission in arrival order (every data-parallel replica agrees
     bit-exactly), then
  2. BATCHED DECODE steps for all admitted sequences.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --rounds 3 --requests 32 --decode-steps 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced_config
from repro.core import engine as ENG
from repro.core import workloads as W
from repro.distributed import meshctx
from repro.launch.mesh import make_host_mesh
from repro.models import model as MDL
from repro.runtime import steps as RT


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = reduced_config(get_arch(args.arch))
    mesh = make_host_mesh()

    # Block-STM admission setup: 4 tenants, shared page pool.
    spec = W.AdmissionSpec(n_tenants=4, n_groups=args.requests,
                           total_pages=args.requests * 4,
                           quota_per_tenant=args.requests * 2)
    ecfg = W.admission_engine_config(spec, args.requests, window=16)
    admit = ENG.make_executor(W.admission_program(spec), ecfg)

    with meshctx.use_mesh(mesh):
        params = MDL.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        step = jax.jit(lambda p, c, t: MDL.decode_step(p, c, t, cfg))
        for rnd in range(args.rounds):
            reqs, storage = W.make_admission_block(spec, args.requests,
                                                   seed=rnd)
            t0 = time.time()
            result = admit(reqs, storage)
            snap = np.asarray(result.snapshot)
            admitted_pages = int(snap[0])
            t_admit = time.time() - t0
            cache = MDL.init_cache(cfg, args.batch, args.max_seq,
                                   jnp.float32)
            toks = jnp.zeros((args.batch,), jnp.int32)
            t0 = time.time()
            for _ in range(args.decode_steps):
                logits, cache = step(params, cache, toks)
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            jax.block_until_ready(toks)
            t_dec = time.time() - t0
            print(f"round {rnd}: admitted {admitted_pages} pages "
                  f"(waves={int(result.waves)}, execs={int(result.execs)}) "
                  f"admit={t_admit*1e3:.1f}ms "
                  f"decode {args.decode_steps} steps={t_dec*1e3:.1f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Trip-count-aware analysis of optimized (post-SPMD) HLO.

``compiled.cost_analysis()`` counts every computation ONCE — including the
bodies of ``while`` loops, so a 96-layer ``lax.scan`` transformer reports
1/96th of its matmul FLOPs and one layer's collectives.  This module walks
the HLO text instead:

  * splits the module into computations and builds per-computation symbol
    tables (instruction name -> shape),
  * extracts per-computation dot/convolution FLOPs and collective bytes,
  * resolves the call graph (while/fusion/calls/to_apply/conditional),
  * reads while trip counts from ``backend_config known_trip_count`` (with a
    loop-condition-constant fallback),
  * aggregates cost from ENTRY with multiplicity = product of trip counts.

Shapes in post-partitioning HLO are per-device, so all results are
per-device-per-step — exactly what the roofline terms need.
"""
from __future__ import annotations

import json
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1, "s4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+"
                     r"\[[\d,]*\](?:\{[^}]*\})?)\s+([\w\-]+)")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def _shape_elems(s: str) -> tuple[Optional[str], int]:
    m = _SHAPE_RE.match(s)
    if not m:
        return None, 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return dt, n


def _all_shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


# opcodes that move HBM bytes (top-level instruction ≈ one kernel; traffic =
# operand reads + result writes, the same convention as XLA 'bytes accessed')
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "copy", "reduce", "reduce-window",
    "scatter", "gather", "sort", "transpose", "broadcast", "concatenate",
    "dynamic-slice", "dynamic-update-slice", "pad", "slice", "select",
    "add", "multiply", "subtract", "divide", "exponential", "log", "tanh",
    "maximum", "minimum", "compare", "convert", "rsqrt", "sqrt", "iota",
    "custom-call", "cholesky", "triangular-solve", "rng", "reverse", "clamp",
}
_NO_TRAFFIC_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "reshape", "opt-barrier",
}


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.bytes = 0.0
        self.collectives = {k: 0.0 for k in COLLECTIVES}
        self.collective_counts = {k: 0 for k in COLLECTIVES}
        self.calls: list[str] = []
        self.call_no_cost: list[str] = []  # fusion internals: no extra traffic
        self.whiles: list[tuple[str, str, Optional[int]]] = []  # body, cond, n
        self.constants: list[int] = []


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.strip()
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            continue
        if line == "}":
            cur = None
            continue
        if cur is not None and line:
            comps[cur].append(line)
    return comps


def _operand_names(line: str, op: str) -> list[str]:
    idx = line.find(f" {op}(")
    if idx < 0:
        idx = line.find(f" {op}-start(")
        op = f"{op}-start"
        if idx < 0:
            return []
    args = line[idx + len(op) + 2:]
    depth = 1
    out, cur = [], ""
    for ch in args:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            if ch == "," and depth == 1:
                out.append(cur.strip())
                cur = ""
            else:
                cur += ch
    if cur.strip():
        out.append(cur.strip())
    # Operands print either bare ("%name") or typed ("f32[8,8]{1,0} %name")
    # depending on the XLA version; the reference is the last token either way.
    names = []
    for o in out:
        tok = o.split()[-1] if o.split() else ""
        if tok.startswith("%"):
            names.append(tok.lstrip("%"))
    return names


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    for name, lines in _split_computations(text).items():
        c = Computation(name)
        shapes: dict[str, str] = {}
        # pass 1: symbol table
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                shapes[dm.group(1)] = dm.group(2)
        # pass 2: costs + edges
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            result_shape, opcode = dm.group(2), dm.group(3)

            base_op = opcode.replace("-start", "").replace("-done", "")
            if base_op not in _NO_TRAFFIC_OPS and not opcode.endswith("-done"):
                rbytes = _all_shape_bytes(result_shape)
                ops = _operand_names(line, opcode)
                obytes = sum(_all_shape_bytes(shapes.get(o, ""))
                             for o in ops)
                # in-place / sparse-access ops: count touched bytes, not the
                # whole buffer (XLA aliases DUS/scatter; gather reads rows).
                if base_op in ("dynamic-update-slice", "scatter"):
                    upd = ops[1] if base_op == "dynamic-update-slice" else \
                        (ops[2] if len(ops) > 2 else ops[-1])
                    c.bytes += 2 * _all_shape_bytes(shapes.get(upd, ""))
                elif base_op in ("gather", "dynamic-slice", "slice"):
                    c.bytes += 2 * rbytes
                elif base_op == "copy":
                    pass  # loop-carry copies; elided/donated on TPU
                else:
                    c.bytes += rbytes + obytes

            if opcode == "dot":
                _, relems = _shape_elems(result_shape.strip("("))
                ops = _operand_names(line, "dot")
                contract = 1
                mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if mc and ops:
                    lhs_shape = shapes.get(ops[0], "")
                    sm = _SHAPE_RE.match(lhs_shape)
                    if sm:
                        ldims = [int(d) for d in sm.group(2).split(",") if d]
                        for i in mc.group(1).split(","):
                            if i and int(i) < len(ldims):
                                contract *= ldims[int(i)]
                c.flops += 2.0 * relems * contract
            elif opcode == "convolution":
                _, relems = _shape_elems(result_shape)
                mw = re.search(r"window=\{size=([\dx]+)", line)
                ksize = 1
                if mw:
                    for d in mw.group(1).split("x"):
                        ksize *= int(d)
                c.flops += 2.0 * relems * ksize
            elif opcode in COLLECTIVES or \
                    opcode.replace("-start", "") in COLLECTIVES:
                base = opcode.replace("-start", "")
                result_bytes = _all_shape_bytes(result_shape)
                ops = _operand_names(line, base)
                operand_bytes = sum(_all_shape_bytes(shapes.get(o, ""))
                                    for o in ops)
                if base == "all-gather":
                    c.collectives[base] += result_bytes
                elif base == "all-reduce":
                    c.collectives[base] += 2 * operand_bytes
                else:
                    c.collectives[base] += operand_bytes
                c.collective_counts[base] += 1
            elif opcode == "while":
                mw_ = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)",
                                line)
                trip = None
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
                if mt:
                    trip = int(mt.group(1))
                if mw_:
                    c.whiles.append((mw_.group(2), mw_.group(1), trip))
            elif opcode == "constant":
                mconst = re.search(r"constant\((\d+)\)", line)
                if mconst and re.match(r"[su]\d+\[\]", result_shape):
                    c.constants.append(int(mconst.group(1)))

            # fusion / reduce internals: count their FLOPs, not their traffic
            for attr in ("calls", "to_apply"):
                ma = re.search(rf"{attr}=%?([\w\.\-]+)", line)
                if ma:
                    c.call_no_cost.append(ma.group(1))
            mb = re.search(r"branch_computations=\{([^}]*)\}", line)
            if mb:
                for callee in mb.group(1).split(","):
                    c.calls.append(callee.strip().lstrip("%"))
        comps[name] = c
    return comps


def aggregate(text: str) -> dict:
    comps = parse_module(text)
    entry = None
    for name in comps:
        if name.split(".")[0] == "main":
            entry = name
    if entry is None:
        called = {x for c in comps.values() for x in c.calls}
        called |= {b for c in comps.values() for b, _, _ in c.whiles}
        called |= {cd for c in comps.values() for _, cd, _ in c.whiles}
        roots = [n for n in comps if n not in called]
        entry = roots[0] if roots else next(iter(comps))

    totals = {"flops": 0.0, "bytes": 0.0, **{k: 0.0 for k in COLLECTIVES},
              **{f"n_{k}": 0.0 for k in COLLECTIVES}}
    stack = []

    def visit(name: str, mult: float, count_bytes: bool):
        if name not in comps or name in stack or mult <= 0:
            return
        c = comps[name]
        stack.append(name)
        totals["flops"] += mult * c.flops
        if count_bytes:
            totals["bytes"] += mult * c.bytes
        for k in COLLECTIVES:
            totals[k] += mult * c.collectives[k]
            totals[f"n_{k}"] += mult * c.collective_counts[k]
        for callee in c.calls:
            visit(callee, mult, count_bytes)
        for callee in c.call_no_cost:
            visit(callee, mult, False)
        for body, cond, trip in c.whiles:
            if trip is None:
                cc = comps.get(cond)
                trip = max(cc.constants) if cc and cc.constants else 1
            visit(cond, mult * trip, count_bytes)
            visit(body, mult * trip, count_bytes)
        stack.pop()

    visit(entry, 1.0, True)
    totals["collective_bytes"] = sum(totals[k] for k in COLLECTIVES)
    totals["entry"] = entry
    return totals

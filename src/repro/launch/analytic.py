"""Analytic per-device HBM-traffic model for the roofline memory term.

Why analytic: the dry-run lowers for the *CPU* backend, which legalizes every
bf16 dot as convert->f32-dot.  Those converts get loop-hoisted into full f32
copies of scanned weights/caches, so byte counts read off the CPU HLO
overstate TPU HBM traffic by 2-10x (the TPU backend has native bf16 MXU ops
and fuses converts).  FLOPs and collective bytes are unaffected (dot shapes
and collective shapes are identical), so those come from the HLO walker;
the memory term comes from this model.

Model (per device, per step), documented term by term in code:
  params:       fwd read + bwd read + remat re-read (train), 1 read (serve)
  grads:        f32 accumulator read+write per microbatch (train)
  optimizer:    p rw + m rw + v rw at their storage dtypes
  activations:  C_layer passes over the (tokens_loc x d_model) stream per
                layer (C≈12 covers norms/proj/residual reads+writes), x3 for
                fwd+remat+bwd when training
  attention:    q/k/v/o kernel traffic (flash kernel: no S^2 HBM traffic)
  scores (dec): decode reads the whole local KV cache per step
  logits:       chunked CE writes+reads each logit once in f32
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as MDL
from repro.optim import adamw
from repro.runtime import steps as RT


def _tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))


def param_bytes(cfg: ArchConfig, dtype=jnp.bfloat16) -> int:
    params = jax.eval_shape(
        lambda: MDL.init_params(jax.random.PRNGKey(0), cfg, dtype))
    return _tree_bytes(params)


def cache_bytes(cfg: ArchConfig, batch: int, seq: int,
                dtype=jnp.bfloat16) -> int:
    cache = jax.eval_shape(lambda: MDL.init_cache(cfg, batch, seq, dtype))
    return _tree_bytes(cache)


def bytes_model(cfg: ArchConfig, shape: ShapeConfig, n_dev: int,
                tp: int = 16) -> dict:
    p_total = param_bytes(cfg)                       # bf16 storage
    p_loc = p_total / (n_dev if cfg.fsdp else tp)
    d = cfg.d_model
    v_loc = cfg.padded_vocab / tp
    tokens_loc = shape.global_batch * shape.seq_len / n_dev  # batch x seq sharding
    kv_dim = cfg.n_kv_heads * cfg.resolved_head_dim
    q_dim = cfg.n_heads * cfg.resolved_head_dim

    out = {}
    if shape.kind == "train":
        m = cfg.train_microbatches
        remat = 1 if cfg.remat == "block" else 0
        out["params"] = m * (2 + remat) * p_loc
        out["grads"] = m * 2 * (p_total * 2 / n_dev)          # f32 accum rw
        mv_bytes = p_total * (1.0 if cfg.opt_state_dtype == "bfloat16" else 2.0)
        out["optimizer"] = 2 * p_loc + 4 * (mv_bytes / (n_dev if cfg.fsdp else tp))
        passes = 2 + remat                                    # fwd+bwd(+remat)
        n_mix_layers = cfg.n_layers + cfg.encoder_layers
        out["activations"] = passes * 12 * n_mix_layers * tokens_loc * d * 2
        if cfg.n_heads:
            out["attention_io"] = passes * 2 * (q_dim + 2 * kv_dim + q_dim) \
                * tokens_loc * cfg.n_layers / max(
                    1, cfg.attn_every if cfg.family == "hybrid" else 1)
        if cfg.n_experts:
            out["moe_dispatch"] = passes * 2 * cfg.top_k * cfg.capacity_factor \
                * tokens_loc * d * 2 * cfg.n_layers
        out["logits"] = 2 * tokens_loc * v_loc * 4
    elif shape.kind == "prefill":
        out["params"] = p_loc
        n_mix_layers = cfg.n_layers + cfg.encoder_layers
        out["activations"] = 12 * n_mix_layers * tokens_loc * d * 2
        if cfg.n_heads:
            out["attention_io"] = 2 * (2 * q_dim + 2 * kv_dim) * tokens_loc \
                * cfg.n_layers
        out["logits"] = 2 * (shape.global_batch / min(n_dev, shape.global_batch)) \
            * v_loc * 4
    else:  # decode
        out["params"] = p_loc
        c_bytes = cache_bytes(cfg, shape.global_batch, shape.seq_len)
        out["cache_read"] = c_bytes / n_dev
        out["cache_write"] = c_bytes / n_dev / max(shape.seq_len, 1)
        b_loc = shape.global_batch / min(n_dev, max(shape.global_batch, 1))
        out["activations"] = 12 * (cfg.n_layers + cfg.encoder_layers) \
            * b_loc * d * 2
        out["logits"] = 2 * b_loc * v_loc * 4
    out["total"] = float(sum(out.values()))
    return out

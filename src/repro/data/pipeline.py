"""Deterministic, resumable synthetic data pipeline.

Production framing: every (host, step) slice of the stream is a pure function
of (seed, step, position) via a counter-based hash — the same property a real
deterministic data service (e.g. array_record + index shuffling) provides.
Consequences used by the framework:
  * restart/elastic resume need only the integer ``step`` from the checkpoint;
  * every data-parallel host computes exactly its shard, no coordination;
  * the stream is identical across mesh shapes (elastic reshape safe).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """xorshift-multiply counter hash (splitmix-style), vectorized."""
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    return x ^ (x >> 16)


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d: dict) -> "DataState":
        return DataState(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticLMStream:
    """Token stream: batch[b, s] = hash(seed, step, b, s) % vocab.

    Labels are next-token (shifted) with -100-style masking handled by the
    loss (labels < 0 ignored); here all positions are valid.
    """

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0,
                 start_step: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.state = DataState(seed=seed, step=start_step)

    def _tokens(self, step: int) -> np.ndarray:
        b, s = self.batch, self.seq
        idx = np.arange(b * (s + 1), dtype=np.uint32).reshape(b, s + 1)
        mixed = _hash_u32(idx ^ np.uint32((step * 2654435761) & 0xFFFFFFFF)
                          ^ np.uint32((self.state.seed * 40503) & 0xFFFFFFFF))
        return (mixed % np.uint32(self.cfg.vocab_size)).astype(np.int32)

    def next_batch(self) -> dict:
        toks = self._tokens(self.state.step)
        self.state.step += 1
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        if self.cfg.frontend != "none" and self.cfg.encoder_layers == 0:
            # vlm stub: embeddings derived deterministically from tokens
            rng = np.random.default_rng(self.state.seed + self.state.step)
            batch = {"embeds": jnp.asarray(
                         rng.standard_normal(
                             (self.batch, self.seq, self.cfg.d_model)),
                         jnp.float32),
                     "labels": batch["labels"]}
        elif self.cfg.encoder_layers:
            rng = np.random.default_rng(self.state.seed + self.state.step)
            batch["frames"] = jnp.asarray(
                rng.standard_normal((self.batch, 8, self.cfg.d_model)),
                jnp.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

"""Jitted train/serve step builders with explicit shardings."""

"""Jitted train/serve step builders with explicit shardings.

``build_train_step``/``build_serve_step`` are shared between the real drivers
(launch/train.py, launch/serve.py) and the multi-pod dry-run — the dry-run
calls ``.lower(...).compile()`` on exactly the artifacts production runs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import meshctx
from repro.models import model as MDL
from repro.optim import adamw

F32 = jnp.float32


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState
    step: jax.Array


def state_specs(cfg: ArchConfig, opt_cfg: Optional[adamw.AdamWConfig] = None):
    pspec = MDL.param_specs(cfg)
    opt_cfg = opt_cfg or adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype)
    ospec = adamw.opt_state_specs(pspec, opt_cfg, meshctx.is_spec)
    return TrainState(
        params=pspec,
        opt=adamw.OptState(m=ospec, v=ospec, step=()),
        step=(),
    )


def _to_shardings(spec_tree, mesh):
    return meshctx.tree_shardings(spec_tree, mesh)


def init_train_state(rng, cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                     dtype=jnp.float32) -> TrainState:
    params = MDL.init_params(rng, cfg, dtype)
    return TrainState(params=params, opt=adamw.init(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    microbatches: int = 1, impl: str = "xla"):
    """(state, batch) -> (state, metrics); grad-accumulation over microbatches."""

    def loss(params, batch):
        return MDL.loss_fn(params, batch, cfg, impl=impl)

    acc_dt = jnp.bfloat16 if cfg.grad_accum_dtype == "bfloat16" else F32
    pspecs = MDL.param_specs(cfg)

    def _constrain_grads(g):
        # pin per-microbatch grads to the parameter sharding so the SPMD
        # partitioner reduce-scatters them instead of all-reducing the full
        # tensor (§Perf: the dominant collective of FSDP training)
        return jax.tree_util.tree_map(
            lambda leaf, spec: meshctx.constrain(leaf, *spec), g, pspecs,
            is_leaf=lambda x: not isinstance(x, dict))

    def train_step(state: TrainState, batch: dict):
        if microbatches > 1:
            def slice_mb(i, x):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def acc_body(carry, i):
                gsum, lsum = carry
                mb = jax.tree_util.tree_map(
                    functools.partial(slice_mb, i), batch)
                l, g = jax.value_and_grad(loss)(state.params, mb)
                g = _constrain_grads(g)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: (a.astype(F32) + b.astype(F32)).astype(acc_dt),
                    gsum, g)
                return (gsum, lsum + l), None

            gzero = _constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params))
            (gsum, lsum), _ = jax.lax.scan(
                acc_body, (gzero, jnp.zeros((), F32)),
                jnp.arange(microbatches))
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, gsum)
            loss_val = lsum / microbatches
        else:
            loss_val, grads = jax.value_and_grad(loss)(state.params, batch)

        new_params, new_opt, om = adamw.update(grads, state.opt, state.params,
                                               opt_cfg)
        metrics = {"loss": loss_val, **om}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def jit_train_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                   opt_cfg: adamw.AdamWConfig, microbatches: int = 1,
                   impl: str = "xla"):
    """jit with explicit in/out shardings + donated state."""
    step_fn = make_train_step(cfg, opt_cfg, microbatches, impl)
    sspec = _to_shardings(state_specs(cfg, opt_cfg), mesh)
    bspec = _to_shardings(MDL.batch_specs(cfg, shape), mesh)
    return jax.jit(step_fn,
                   in_shardings=(sspec, bspec),
                   out_shardings=(sspec, None),
                   donate_argnums=(0,))


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, cache, tokens):
        logits, new_cache = MDL.decode_step(params, cache, tokens, cfg)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_cache
    return serve_step


def serve_cfg(cfg: ArchConfig, hbm_budget_bytes: float = 14e9) -> ArchConfig:
    """Inference sharding policy: FSDP means re-gathering every weight on
    every decode step (§Perf cell 3: 0.55 s/token of pure all-gather for
    qwen1.5-110b).  Drop FSDP for serving whenever TP-resident parameters fit
    the HBM budget.  Sequence-parallel archs (q_heads % tp != 0) replicate
    their attention weights over the model axis, so those count at full size.
    """
    if not cfg.fsdp:
        return cfg
    tp = 16
    from repro.models.layers import attn_mode
    hd = cfg.resolved_head_dim
    attn_params = cfg.n_layers * hd * cfg.d_model * \
        (2 * cfg.n_heads + 2 * cfg.n_kv_heads)
    if attn_mode(cfg, tp) == "sequence":
        per_dev = 2 * (attn_params + (cfg.param_count() - attn_params) / tp)
    else:
        per_dev = 2 * cfg.param_count() / tp
    if per_dev <= hbm_budget_bytes:
        return dataclasses.replace(cfg, fsdp=False)
    return cfg


def jit_serve_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                   dtype=jnp.bfloat16):
    cfg = serve_cfg(cfg)
    serve_fn = make_serve_step(cfg)
    pspec = _to_shardings(MDL.param_specs(cfg), mesh)
    # caches/tokens: sanitize against concrete shapes (global_batch may be 1)
    cache_struct_ = jax.eval_shape(
        lambda: MDL.init_cache(cfg, shape.global_batch, shape.seq_len, dtype))
    cspec = meshctx.tree_shardings_for(MDL.cache_specs(cfg), cache_struct_,
                                       mesh)
    tok_struct = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    tspec = meshctx.tree_shardings_for((meshctx.BATCH,), tok_struct, mesh)
    return jax.jit(serve_fn,
                   in_shardings=(pspec, cspec, tspec),
                   out_shardings=(tspec, cspec),
                   donate_argnums=(1,))


# ---------------------------------------------------------------------------
# dry-run structures: ShapeDtypeStruct trees matching the above signatures
# ---------------------------------------------------------------------------

def train_state_struct(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                       dtype=jnp.bfloat16) -> TrainState:
    params = jax.eval_shape(
        lambda: MDL.init_params(jax.random.PRNGKey(0), cfg, dtype))
    moments = jax.eval_shape(lambda: adamw.init(params, opt_cfg))
    return TrainState(
        params=params,
        opt=moments,
        step=jax.ShapeDtypeStruct((), jnp.int32))


def cache_struct(cfg: ArchConfig, batch: int, max_seq: int,
                 dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: MDL.init_cache(cfg, batch, max_seq, dtype))

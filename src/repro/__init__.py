"""repro: Block-STM on TPU — deterministic parallel block execution (JAX)
+ a multi-pod LM training/serving framework. See README.md / DESIGN.md."""

__version__ = "1.0.0"

"""Async, atomic, keep-K checkpoint manager with elastic restore.

Layout:  <dir>/step_<N>/arrays.npz + meta.json, written to a ``.tmp`` staging
directory and atomically renamed — a preempted writer never corrupts the
latest checkpoint.  ``save`` offloads serialization to a background thread
(async checkpointing); ``wait`` joins it (called before the next save and at
exit).  Restore rebuilds the pytree from the saved key paths and can re-shard
onto a *different* mesh than the one that wrote it (elastic scaling): arrays
are loaded host-side and ``jax.device_put`` with the new sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        # Materialize on host *before* handing to the writer thread so the
        # training loop can donate/overwrite device buffers immediately.
        flat = _flatten(tree)
        meta = {"step": int(step), "extra": extra or {}}

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.startswith(".tmp"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into ``template``'s structure/dtypes.

        ``shardings``: optional matching pytree of NamedSharding — enables
        restoring onto a different mesh than the writer's (elastic resume).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, meta

"""Async, atomic, keep-K checkpointing with elastic restore."""

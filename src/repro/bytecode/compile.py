"""Lowerings of the Python-DSL workloads to bytecode.

Each ``compile_*`` mirrors its :mod:`repro.core.workloads` counterpart
read-for-read and write-for-write, so the compiled program is txn-for-txn
equivalent to the traced DSL program (property-tested in
``tests/test_bytecode.py``).

Every compiler takes a ``loc_base`` so a mixed block can lay the three
contract families out in one disjoint location universe:

  [0, p2p.n_locs)                                — balances/seqnos/chain-cfg
  [p2p.n_locs, p2p.n_locs + indirect.n_locs)     — pointer cells + targets
  [.., + admission.n_locs)                       — free-list head + quotas

For ``indirect``, pointer *values* stored in memory are absolute locations:
the block generator offsets the initial pointers and the ``new_target``
params by the region base, so the program itself only rebases the static
``slot`` id.
"""
from __future__ import annotations

import numpy as np

from repro.bytecode.assembler import Assembler, Program
from repro.bytecode.interp import BytecodeVM
from repro.core.types import EngineConfig
from repro.core.workloads import AdmissionSpec, IndirectSpec, P2PSpec

# Flat-arg vector layout per family (LOAD_PARAM indices).
P2P_ARGS = ("src", "dst", "amount")
INDIRECT_ARGS = ("slot", "delta", "new_target", "repoint")
ADMISSION_ARGS = ("tenant", "group", "pages")


def compile_p2p(spec: P2PSpec, loc_base: int = 0) -> Program:
    """Lower ``p2p_program``: cfg reads, balance transfer, seqno bumps."""
    a = Assembler()
    cfg_base = loc_base + 2 * spec.n_accounts
    for k in range(spec.cfg_reads):
        a.read(a.imm(cfg_base + k))
    src, dst, amt = a.param(0), a.param(1), a.param(2)
    two, base = a.imm(2), a.imm(loc_base)
    src_bal_loc = a.add(a.mul(src, two), base)
    dst_bal_loc = a.add(a.mul(dst, two), base)
    src_bal = a.read(src_bal_loc)
    dst_bal = a.read(dst_bal_loc)
    ok = a.ge(src_bal, amt)                    # conditional => dynamic write set
    a.write(src_bal_loc, a.sub(src_bal, amt), enable=ok)
    a.write(dst_bal_loc, a.add(dst_bal, amt), enable=ok)
    if spec.write_seqno:
        one = a.imm(1)
        src_seq_loc = a.add(src_bal_loc, one)
        dst_seq_loc = a.add(dst_bal_loc, one)
        src_seq = a.read(src_seq_loc)
        dst_seq = a.read(dst_seq_loc)
        a.write(src_seq_loc, a.add(src_seq, one))
        a.write(dst_seq_loc, a.add(dst_seq, one), enable=ok)
    return a.build()


def compile_indirect(spec: IndirectSpec, loc_base: int = 0) -> Program:
    """Lower ``indirect_program``: pointer chase with occasional repoint."""
    a = Assembler()
    slot_loc = a.add(a.param(0), a.imm(loc_base))
    target = a.read(slot_loc)                  # hop 1: discover the target
    val = a.read(target)                       # hop 2: dynamic location
    a.write(target, a.add(val, a.param(1)))    # RMW on the discovered cell
    a.write(slot_loc, a.param(2), enable=a.param(3))
    return a.build()


def compile_admission(spec: AdmissionSpec, loc_base: int = 0) -> Program:
    """Lower ``admission_program``: page allocation against head + quota."""
    a = Assembler()
    head = a.read(a.imm(loc_base))             # free-list head (hot!)
    tenant, group, pages = a.param(0), a.param(1), a.param(2)
    used_loc = a.add(tenant, a.imm(loc_base + 1))
    used = a.read(used_loc)
    grp_loc = a.add(group, a.imm(loc_base + 1 + spec.n_tenants))
    grp = a.read(grp_loc)
    new_head = a.add(head, pages)
    new_used = a.add(used, pages)
    fits = a.and_(a.le(new_head, a.imm(spec.total_pages)),
                  a.le(new_used, a.imm(spec.quota_per_tenant)))
    a.write(a.imm(loc_base), new_head, enable=fits)
    a.write(used_loc, new_used, enable=fits)
    a.write(grp_loc, a.add(grp, pages), enable=fits)
    return a.build()


def compile_admission_hashed(spec: AdmissionSpec, loc_base: int = 0,
                             salt: int = 17) -> Program:
    """Admission with HASH/MOD key derivation done *in bytecode*.

    The tenant-quota and group-count slots are derived from the raw ids via
    ``hash_mix(id, salt) mod n`` — the admission-style key derivation
    (sharding an id universe onto a fixed slot table) that previously needed
    host-side precomputation because the ISA had no DIV/MOD/HASH.  No DSL
    counterpart exists; the sequential ``BytecodeVM.__call__`` oracle is the
    ground truth (see ``tests/test_conformance.py``).
    """
    from repro.bytecode import isa

    a = Assembler()
    head = a.read(a.imm(loc_base))             # free-list head (hot!)
    tenant, group, pages = a.param(0), a.param(1), a.param(2)
    salt_r = a.imm(isa.signed32(salt))
    tslot = a.mod(a.hash_(tenant, salt_r), a.imm(spec.n_tenants))
    used_loc = a.add(tslot, a.imm(loc_base + 1))
    used = a.read(used_loc)
    gslot = a.mod(a.hash_(group, salt_r), a.imm(spec.n_groups))
    grp_loc = a.add(gslot, a.imm(loc_base + 1 + spec.n_tenants))
    grp = a.read(grp_loc)
    new_head = a.add(head, pages)
    new_used = a.add(used, pages)
    fits = a.and_(a.le(new_head, a.imm(spec.total_pages)),
                  a.le(new_used, a.imm(spec.quota_per_tenant)))
    a.write(a.imm(loc_base), new_head, enable=fits)
    a.write(used_loc, new_used, enable=fits)
    a.write(grp_loc, a.add(grp, pages), enable=fits)
    return a.build()


# ---------------------------------------------------------------------------
# Block assembly helpers
# ---------------------------------------------------------------------------

def pack_args(params: dict, order: tuple[str, ...], n_slots: int) -> np.ndarray:
    """dict of (n,) arrays -> (n, n_slots) int32 flat-arg matrix."""
    cols = [np.asarray(params[name], np.int32) for name in order]
    n = cols[0].shape[0]
    out = np.zeros((n, n_slots), np.int32)
    for j, col in enumerate(cols):
        out[:, j] = col
    return out


def homogeneous_block_params(prog: Program, args: np.ndarray) -> dict:
    """Replicate one program across the block: (code, args) per txn."""
    import jax.numpy as jnp
    n = args.shape[0]
    code = np.broadcast_to(prog.code[None], (n,) + prog.code.shape)
    return {"code": jnp.asarray(np.ascontiguousarray(code)),
            "args": jnp.asarray(args)}


def vm_and_config(progs: list[Program], n_txns: int, n_locs: int,
                  dispatch: str = "gather",
                  **cfg_kw) -> tuple[BytecodeVM, EngineConfig]:
    """Interpreter + engine config sized for the union of ``progs``."""
    cfg = EngineConfig(
        n_txns=n_txns, n_locs=n_locs,
        max_reads=max(p.n_reads for p in progs),
        max_writes=max(p.n_writes for p in progs),
        **cfg_kw)
    vm = BytecodeVM(n_regs=max(p.n_regs for p in progs), dispatch=dispatch)
    return vm, cfg


def pad_common(progs: list[Program]) -> list[Program]:
    """Pad every program to the longest op count (one block = one L)."""
    L = max(p.code.shape[0] for p in progs)
    return [p.padded(L) for p in progs]

"""Builder API for bytecode programs.

:class:`Assembler` allocates registers and emits instructions through a small
expression-style surface::

    a = Assembler()
    src = a.param(0)                       # r <- args[0]
    bal = a.read(a.add(a.mul(src, a.imm(2)), a.imm(base)))
    ok  = a.ge(bal, a.param(2))
    a.write(loc_reg, val_reg, enable=ok)   # conditionally-enabled write
    a.halt()
    prog = a.build()

:class:`Program` carries the padded ``(L, 4)`` int32 op array plus the static
metadata the engine config needs: register-file size, flat-arg count, and the
READ/WRITE op counts that bound ``max_reads``/``max_writes``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.bytecode import isa


@dataclasses.dataclass(frozen=True, eq=False)  # eq/hash by identity: the
class Program:                                 # ndarray field breaks value eq
    """A compiled transaction program (pure data)."""

    code: np.ndarray    # (L, 4) int32, HALT-padded
    n_regs: int         # registers used (max index + 1)
    n_params: int       # flat-arg slots referenced (max index + 1)
    n_reads: int        # READ op count  -> lower bound for cfg.max_reads
    n_writes: int       # WRITE op count -> lower bound for cfg.max_writes

    def padded(self, length: int) -> "Program":
        """Pad (never truncate) the op array to ``length`` rows of HALT."""
        L = self.code.shape[0]
        if length < L:
            raise ValueError(f"cannot pad length {L} program to {length}")
        pad = np.zeros((length - L, isa.N_FIELDS), np.int32)
        pad[:, 0] = isa.HALT
        return dataclasses.replace(
            self, code=np.concatenate([self.code, pad], axis=0))

    def disassemble(self) -> str:
        return isa.disassemble(self.code)


class Assembler:
    """Emits one program; registers are allocated, never freed (SSA-ish)."""

    def __init__(self):
        self._ops: list[tuple[int, int, int, int]] = []
        self._next_reg = 0
        self._n_params = 0
        self._n_reads = 0
        self._n_writes = 0
        self._halted = False

    # -- register allocation -------------------------------------------------
    def reg(self) -> int:
        r = self._next_reg
        self._next_reg += 1
        return r

    def _emit(self, op: int, a: int = 0, b: int = 0, c: int = 0) -> None:
        if self._halted:
            raise ValueError("program already HALTed")
        for f in (a, b, c):
            if not (-2**31 <= f < 2**31):
                raise ValueError(f"field {f} overflows int32")
        self._ops.append((op, a, b, c))

    # -- values --------------------------------------------------------------
    def param(self, idx: int) -> int:
        """r <- args[idx]."""
        if idx < 0:
            raise ValueError("param index must be >= 0")
        self._n_params = max(self._n_params, idx + 1)
        r = self.reg()
        self._emit(isa.LOAD_PARAM, r, idx)
        return r

    def imm(self, value: int) -> int:
        r = self.reg()
        self._emit(isa.LOAD_IMM, r, int(value))
        return r

    def mov(self, src: int) -> int:
        r = self.reg()
        self._emit(isa.MOV, r, src)
        return r

    # -- memory --------------------------------------------------------------
    def read(self, loc: int, *, enable: int | None = None) -> int:
        """r <- mem[regs[loc]]; a disabled read yields 0."""
        self._n_reads += 1
        r = self.reg()
        self._emit(isa.READ, r, loc, isa.ALWAYS if enable is None else enable)
        return r

    def write(self, loc: int, value: int, *, enable: int | None = None) -> None:
        """mem[regs[loc]] <- regs[value], gated on regs[enable] != 0."""
        self._n_writes += 1
        self._emit(isa.WRITE, loc, value,
                   isa.ALWAYS if enable is None else enable)

    # -- ALU -----------------------------------------------------------------
    def _binop(self, op: int, x: int, y: int) -> int:
        r = self.reg()
        self._emit(op, r, x, y)
        return r

    def add(self, x: int, y: int) -> int:
        return self._binop(isa.ADD, x, y)

    def sub(self, x: int, y: int) -> int:
        return self._binop(isa.SUB, x, y)

    def mul(self, x: int, y: int) -> int:
        return self._binop(isa.MUL, x, y)

    def ge(self, x: int, y: int) -> int:
        return self._binop(isa.GE, x, y)

    def le(self, x: int, y: int) -> int:
        return self._binop(isa.LE, x, y)

    def and_(self, x: int, y: int) -> int:
        return self._binop(isa.AND, x, y)

    def div(self, x: int, y: int) -> int:
        """Floor division; division by zero yields 0."""
        return self._binop(isa.DIV, x, y)

    def mod(self, x: int, y: int) -> int:
        """Floor modulo (sign of divisor); modulo by zero yields 0."""
        return self._binop(isa.MOD, x, y)

    def hash_(self, x: int, y: int) -> int:
        """murmur3-style int32 mix of (x, y) — see ``isa.hash_mix``."""
        return self._binop(isa.HASH, x, y)

    def select(self, cond: int, x: int, y: int) -> int:
        """r <- regs[cond] != 0 ? regs[x] : regs[y] (non-destructive)."""
        r = self.mov(cond)
        self._emit(isa.SELECT, r, x, y)
        return r

    def halt(self) -> None:
        self._emit(isa.HALT)
        self._halted = True

    # -- finalization --------------------------------------------------------
    def build(self, pad_to: int | None = None) -> Program:
        if not self._halted:
            self.halt()
        code = np.asarray(self._ops, np.int32).reshape(-1, isa.N_FIELDS)
        prog = Program(code=code, n_regs=max(self._next_reg, 1),
                       n_params=self._n_params, n_reads=self._n_reads,
                       n_writes=self._n_writes)
        return prog if pad_to is None else prog.padded(pad_to)

"""Register mini-ISA for transaction programs.

An instruction is a row of 4 int32 fields ``[op, a, b, c]``; a program is a
fixed-shape ``(L, 4)`` int32 array, padded with HALT rows.  Registers hold
``value_dtype`` scalars (int32); location ids and data values share the
register file, which is what makes dynamic read sets (`READ` of a computed
location) expressible.

Operand conventions (see README.md for the full table):

  HALT                          stop; every later op is a no-op
  LOAD_PARAM  r[a] = params[b]  b indexes the txn's flat arg vector
  LOAD_IMM    r[a] = b          b is a signed immediate
  MOV         r[a] = r[b]
  READ        r[a] = mem[r[b]]  enable mask in register c (c < 0: always on)
  WRITE       mem[r[a]] = r[b]  enable mask in register c (c < 0: always on)
  ADD/SUB/MUL r[a] = r[b] op r[c]
  GE/LE       r[a] = r[b] >= r[c]  (resp. <=), as 0/1
  AND         r[a] = (r[b] != 0) & (r[c] != 0), as 0/1
  SELECT      r[a] = r[a] != 0 ? r[b] : r[c]
  DIV/MOD     r[a] = r[b] floordiv/floormod r[c]; by-zero yields 0
  HASH        r[a] = mix32(r[b], r[c])  (murmur3-style finalizer, see hash_mix)

``READ``/``WRITE`` are the only externally-visible ops: they consume one
read/write slot each time they execute (whether or not their enable mask is
on), mirroring the static call-site slot accounting of the Python DSL — so
``EngineConfig.max_reads/max_writes`` must bound the per-program READ/WRITE
op counts, which the assembler records on :class:`~repro.bytecode.assembler.Program`.
"""
from __future__ import annotations

HALT = 0
LOAD_PARAM = 1
LOAD_IMM = 2
MOV = 3
READ = 4
WRITE = 5
ADD = 6
SUB = 7
MUL = 8
GE = 9
LE = 10
AND = 11
SELECT = 12
DIV = 13
MOD = 14
HASH = 15

N_OPCODES = 16

ALWAYS = -1        # enable-operand sentinel: unconditionally enabled
N_FIELDS = 4       # [op, a, b, c]

# Pure register->register ops: exactly the set the interpreter's branch-free
# gather/select ALU dispatches (everything except HALT and the memory ops).
ALU_OPS = (LOAD_PARAM, LOAD_IMM, MOV, ADD, SUB, MUL, GE, LE, AND, SELECT,
           DIV, MOD, HASH)

MNEMONICS = {
    HALT: "HALT", LOAD_PARAM: "LOAD_PARAM", LOAD_IMM: "LOAD_IMM", MOV: "MOV",
    READ: "READ", WRITE: "WRITE", ADD: "ADD", SUB: "SUB", MUL: "MUL",
    GE: "GE", LE: "LE", AND: "AND", SELECT: "SELECT",
    DIV: "DIV", MOD: "MOD", HASH: "HASH",
}

# HASH is a murmur3-style finalizer over the pair (r[b], r[c]): good enough
# dispersion for key derivation (tenant -> quota slot) while staying pure
# int32 wrap-around arithmetic, so the JAX and Python interpreters agree
# bit-for-bit.  Constants are the murmur3/golden-ratio mix constants.
HASH_C1 = 0x9E3779B1
HASH_C2 = 0x85EBCA6B
HASH_C3 = 0xC2B2AE35


def signed32(v: int) -> int:
    """Reinterpret an arbitrary int as a two's-complement signed int32."""
    return ((int(v) & 0xFFFFFFFF) ^ 0x80000000) - 0x80000000


def hash_mix(x: int, y: int) -> int:
    """Reference HASH semantics (pure Python, uint32 arithmetic, signed out)."""
    M = 0xFFFFFFFF
    h = ((x & M) ^ ((y * HASH_C1) & M)) & M
    h ^= h >> 16
    h = (h * HASH_C2) & M
    h ^= h >> 13
    h = (h * HASH_C3) & M
    h ^= h >> 16
    return signed32(h)


def disassemble(code) -> str:
    """Human-readable listing of an ``(L, 4)`` op array (stops at first HALT)."""
    import numpy as np
    lines = []
    for i, (op, a, b, c) in enumerate(np.asarray(code)):
        name = MNEMONICS.get(int(op), f"?{int(op)}")
        lines.append(f"{i:3d}: {name:<10} a={int(a):<4} b={int(b):<4} c={int(c)}")
        if int(op) == HALT:
            break
    return "\n".join(lines)

"""Register mini-ISA for transaction programs.

An instruction is a row of 4 int32 fields ``[op, a, b, c]``; a program is a
fixed-shape ``(L, 4)`` int32 array, padded with HALT rows.  Registers hold
``value_dtype`` scalars (int32); location ids and data values share the
register file, which is what makes dynamic read sets (`READ` of a computed
location) expressible.

Operand conventions (see README.md for the full table):

  HALT                          stop; every later op is a no-op
  LOAD_PARAM  r[a] = params[b]  b indexes the txn's flat arg vector
  LOAD_IMM    r[a] = b          b is a signed immediate
  MOV         r[a] = r[b]
  READ        r[a] = mem[r[b]]  enable mask in register c (c < 0: always on)
  WRITE       mem[r[a]] = r[b]  enable mask in register c (c < 0: always on)
  ADD/SUB/MUL r[a] = r[b] op r[c]
  GE/LE       r[a] = r[b] >= r[c]  (resp. <=), as 0/1
  AND         r[a] = (r[b] != 0) & (r[c] != 0), as 0/1
  SELECT      r[a] = r[a] != 0 ? r[b] : r[c]

``READ``/``WRITE`` are the only externally-visible ops: they consume one
read/write slot each time they execute (whether or not their enable mask is
on), mirroring the static call-site slot accounting of the Python DSL — so
``EngineConfig.max_reads/max_writes`` must bound the per-program READ/WRITE
op counts, which the assembler records on :class:`~repro.bytecode.assembler.Program`.
"""
from __future__ import annotations

HALT = 0
LOAD_PARAM = 1
LOAD_IMM = 2
MOV = 3
READ = 4
WRITE = 5
ADD = 6
SUB = 7
MUL = 8
GE = 9
LE = 10
AND = 11
SELECT = 12

N_OPCODES = 13

ALWAYS = -1        # enable-operand sentinel: unconditionally enabled
N_FIELDS = 4       # [op, a, b, c]

MNEMONICS = {
    HALT: "HALT", LOAD_PARAM: "LOAD_PARAM", LOAD_IMM: "LOAD_IMM", MOV: "MOV",
    READ: "READ", WRITE: "WRITE", ADD: "ADD", SUB: "SUB", MUL: "MUL",
    GE: "GE", LE: "LE", AND: "AND", SELECT: "SELECT",
}


def disassemble(code) -> str:
    """Human-readable listing of an ``(L, 4)`` op array (stops at first HALT)."""
    import numpy as np
    lines = []
    for i, (op, a, b, c) in enumerate(np.asarray(code)):
        name = MNEMONICS.get(int(op), f"?{int(op)}")
        lines.append(f"{i:3d}: {name:<10} a={int(a):<4} b={int(b):<4} c={int(c)}")
        if int(op) == HALT:
            break
    return "\n".join(lines)

"""Bytecode transaction VM: programs as data.

The Python-DSL VM (:mod:`repro.core.vm`) requires every transaction in a block
to be the *same* traced Python function — heterogeneous blocks force one XLA
compile per contract.  This package makes transaction programs int32 arrays
interpreted inside the wave engine, so ONE jitted executor serves arbitrary
mixes of contracts with zero recompiles:

* :mod:`repro.bytecode.isa`       — the register mini-ISA (opcodes, encoding)
* :mod:`repro.bytecode.interp`    — ``lax.scan`` interpreter with a
  branch-free gather/select ALU (``lax.switch`` only for READ/WRITE)
* :mod:`repro.bytecode.assembler` — builder API emitting ``Program`` objects
* :mod:`repro.bytecode.compile`   — lowerings of the three DSL workloads

See ``src/repro/bytecode/README.md`` for the ISA reference.
"""
from repro.bytecode.assembler import Assembler, Program
from repro.bytecode.interp import BytecodeVM

__all__ = ["Assembler", "Program", "BytecodeVM"]

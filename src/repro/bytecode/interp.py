"""Bytecode interpreter: one ``lax.scan`` over ops, branch-free ALU dispatch.

:class:`BytecodeVM` runs a transaction whose *program is data* — the txn's
params carry ``code`` ``(L, 4)`` int32 and ``args`` ``(P,)`` int32 — inside
the same two harnesses as the Python DSL programs of :mod:`repro.core.vm`:

* ``execute_spec`` — speculative JAX execution in the wave engine.  It mirrors
  :class:`~repro.core.vm.SpecCtx` semantics exactly (read-own-write first,
  then the MV resolver; ESTIMATE hits set ``blocked``; latest-write-per-
  location dedup) but with *traced* slot counters, because slots are consumed
  by data-dependent READ/WRITE ops rather than static Python call sites.  The
  result is a standard :class:`~repro.core.types.ExecResult`, so dependency
  detection, validation, and the commit frontier are untouched.
* ``__call__(p, ctx)`` — plain-Python interpretation against
  :class:`~repro.core.vm.OracleCtx`, so ``run_sequential`` accepts a
  :class:`BytecodeVM` directly as the ground-truth reference.

Dispatch (``dispatch='gather'``, the default): pure register ops
(:data:`isa.ALU_OPS`) do NOT go through ``lax.switch``.  Every step computes
the small vector of all ALU candidate results from the gathered operands and
selects one by opcode — a gather/select ALU with a single register-file
scatter.  ``lax.switch`` is reserved for the ops with side effects beyond the
register file (READ / WRITE, 3 branches incl. the no-op).  Under ``vmap`` a
switch lowers to computing every branch and selecting per lane, so shrinking
the branch set from one-per-opcode to 3 removes ~13 register-file scatters
per executed op — the interpreter fast-path (measured in
``benchmarks/engine_bench.py --workload bytecode``; record:
``BENCH_baselines.json``).  ``dispatch='switch'`` keeps the original
one-branch-per-opcode ``lax.switch`` as the measured baseline.

Cost model: a wave executes ``window`` txns × ``L`` ops; each op costs one
O(#ALU_OPS) candidate vector + one scatter, plus the READ/WRITE branches'
O(max_reads + max_writes) scalar work and one MV resolve per READ op.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.bytecode import isa
from repro.core.types import NO_LOC, STORAGE, EngineConfig, ExecResult

_DISPATCH_MODES = ("gather", "switch")

# opcode -> slot in the ALU candidate vector; -1 marks non-ALU ops (memory /
# control), which leave the register file untouched on the ALU path.
_ALU_SLOT = np.full((isa.N_OPCODES,), -1, np.int32)
for _i, _op in enumerate(isa.ALU_OPS):
    _ALU_SLOT[_op] = _i


def _div(x, y):
    """Floor division with DIV-by-zero -> 0 (int32, wrap on INT_MIN / -1)."""
    safe_y = jnp.where((y == 0) | (y == -1), 1, y)
    q = jnp.floor_divide(x, safe_y)
    q = jnp.where(y == -1, -x, q)          # -x wraps INT_MIN like Python _i32
    return jnp.where(y == 0, 0, q)


def _mod(x, y):
    """Floor modulo (sign of divisor) with MOD-by-zero -> 0."""
    safe_y = jnp.where((y == 0) | (y == -1), 1, y)   # x mod ±1 == 0
    return jnp.where(y == 0, 0, jnp.remainder(x, safe_y))


def _hash(x, y):
    """murmur3-style finalizer over (x, y); bit-identical to isa.hash_mix."""
    i32 = jnp.int32
    c1 = jnp.asarray(isa.signed32(isa.HASH_C1), i32)
    c2 = jnp.asarray(isa.signed32(isa.HASH_C2), i32)
    c3 = jnp.asarray(isa.signed32(isa.HASH_C3), i32)
    srl = jax.lax.shift_right_logical
    h = x.astype(i32) ^ (y.astype(i32) * c1)
    h = h ^ srl(h, 16)
    h = h * c2
    h = h ^ srl(h, 13)
    h = h * c3
    h = h ^ srl(h, 16)
    return h


class _VMState(NamedTuple):
    """Scan carry: register file + the SpecCtx-equivalent record arrays."""

    regs: jax.Array          # (n_regs,) value_dtype
    read_locs: jax.Array     # (R,) i32
    read_writer: jax.Array   # (R,) i32
    read_inc: jax.Array      # (R,) i32
    write_locs: jax.Array    # (W,) i32
    write_vals: jax.Array    # (W,) value_dtype
    r: jax.Array             # () i32 next read slot
    w: jax.Array             # () i32 next write slot
    blocked: jax.Array       # () bool
    blocker: jax.Array       # () i32
    done: jax.Array          # () bool (HALT reached)


class BytecodeVM:
    """Interpreter for ``(code, args)`` transactions.

    ``n_regs`` is the static register-file size (>= max register index + 1
    across every program that may appear in a block).  ``dispatch`` selects
    the arithmetic dispatch strategy: ``'gather'`` (branch-free ALU, default)
    or ``'switch'`` (legacy one-``lax.switch``-branch-per-opcode baseline).
    """

    def __init__(self, n_regs: int, dispatch: str = "gather"):
        if n_regs < 1:
            raise ValueError("n_regs must be >= 1")
        if dispatch not in _DISPATCH_MODES:
            raise ValueError(f"dispatch must be one of {_DISPATCH_MODES}, "
                             f"got {dispatch!r}")
        self.n_regs = n_regs
        self.dispatch = dispatch

    # -- speculative path (wave engine) -------------------------------------
    def execute_spec(self, cfg: EngineConfig, txn_idx: jax.Array, resolver,
                     value_reader, p) -> ExecResult:
        code = jnp.asarray(p["code"], jnp.int32)
        args = jnp.asarray(p["args"], cfg.value_dtype)
        n_regs, R, W = self.n_regs, cfg.max_reads, cfg.max_writes
        vdt = cfg.value_dtype

        def creg(i):
            return jnp.clip(i, 0, n_regs - 1)

        def enab(st, c):
            return jnp.where(c < 0, True, st.regs[creg(c)] != 0)

        def set_reg(st, i, v):
            return st._replace(regs=st.regs.at[creg(i)].set(v.astype(vdt)))

        def op_noop(st, a, b, c):
            return st

        def op_halt(st, a, b, c):
            return st._replace(done=jnp.asarray(True))

        def op_read(st, a, b, c):
            loc = st.regs[creg(b)].astype(jnp.int32)
            enabled = enab(st, c) & ~st.blocked
            eff_loc = jnp.where(enabled, loc, NO_LOC)
            # read-own-write: write-time dedup keeps at most one live match.
            own = st.write_locs == eff_loc
            own_hit = own.any()
            own_val = jnp.where(own, st.write_vals, 0).sum().astype(vdt)
            res = resolver(eff_loc, txn_idx)
            mv_val = value_reader(res, eff_loc)
            value = jnp.where(own_hit, own_val, mv_val)
            value = jnp.where(enabled, value, 0).astype(vdt)
            rec = enabled & ~own_hit
            slot = jnp.clip(st.r, 0, R - 1)
            st = st._replace(
                read_locs=st.read_locs.at[slot].set(
                    jnp.where(rec, eff_loc, NO_LOC)),
                read_writer=st.read_writer.at[slot].set(
                    jnp.where(rec & res.found, res.writer, STORAGE)),
                read_inc=st.read_inc.at[slot].set(
                    jnp.where(rec & res.found, res.inc, -1)),
                r=st.r + 1,
            )
            hit_est = rec & res.is_estimate & ~st.blocked
            st = st._replace(
                blocker=jnp.where(hit_est, res.writer, st.blocker),
                blocked=st.blocked | hit_est,
            )
            return set_reg(st, a, value)

        def op_write(st, a, b, c):
            loc = st.regs[creg(a)].astype(jnp.int32)
            value = st.regs[creg(b)]
            enabled = enab(st, c) & ~st.blocked
            # latest-value-per-location: kill earlier live slots on this loc.
            wlocs = jnp.where(enabled & (st.write_locs == loc), NO_LOC,
                              st.write_locs)
            slot = jnp.clip(st.w, 0, W - 1)
            return st._replace(
                write_locs=wlocs.at[slot].set(jnp.where(enabled, loc, NO_LOC)),
                write_vals=st.write_vals.at[slot].set(
                    jnp.where(enabled, value, 0).astype(vdt)),
                w=st.w + 1,
            )

        # ONE semantics table serves both dispatch modes: each entry maps the
        # gathered operands (x=r[b], y=r[c], sel=r[a], b=raw field) to the
        # destination value.  Order/membership comes from isa.ALU_OPS alone.
        alu_fns = {
            isa.LOAD_PARAM: lambda x, y, sel, b:
                args[jnp.clip(b, 0, args.shape[0] - 1)],
            isa.LOAD_IMM: lambda x, y, sel, b: b.astype(vdt),
            isa.MOV: lambda x, y, sel, b: x,
            isa.ADD: lambda x, y, sel, b: x + y,
            isa.SUB: lambda x, y, sel, b: x - y,
            isa.MUL: lambda x, y, sel, b: x * y,
            isa.GE: lambda x, y, sel, b: (x >= y).astype(vdt),
            isa.LE: lambda x, y, sel, b: (x <= y).astype(vdt),
            isa.AND: lambda x, y, sel, b: ((x != 0) & (y != 0)).astype(vdt),
            isa.SELECT: lambda x, y, sel, b: jnp.where(sel != 0, x, y),
            isa.DIV: lambda x, y, sel, b: _div(x, y),
            isa.MOD: lambda x, y, sel, b: _mod(x, y),
            isa.HASH: lambda x, y, sel, b: _hash(x, y),
        }
        assert set(alu_fns) == set(isa.ALU_OPS)

        def alu_operands(st, a, b, c):
            return st.regs[creg(b)], st.regs[creg(c)], st.regs[creg(a)], b

        def alu_apply(st, op, a, b, c):
            x, y, sel, b = alu_operands(st, a, b, c)
            cands = jnp.stack([alu_fns[o](x, y, sel, b).astype(vdt)
                               for o in isa.ALU_OPS])
            slot = jnp.asarray(_ALU_SLOT)[op]
            is_alu = slot >= 0
            out = cands[jnp.clip(slot, 0, cands.shape[0] - 1)]
            dst = creg(a)
            return st._replace(regs=st.regs.at[dst].set(
                jnp.where(is_alu, out, st.regs[dst]).astype(vdt)))

        def step_gather(st: _VMState, row):
            op, a, b, c = row[0], row[1], row[2], row[3]
            # undefined opcode traps to HALT (never silently runs another op)
            op = jnp.where((op >= 0) & (op < isa.N_OPCODES), op, isa.HALT)
            new = alu_apply(st, op, a, b, c)          # no-op for non-ALU ops
            mem = jnp.where(op == isa.READ, 1,
                            jnp.where(op == isa.WRITE, 2, 0))
            new = jax.lax.switch(mem, [op_noop, op_read, op_write],
                                 new, a, b, c)
            new = new._replace(done=new.done | (op == isa.HALT))
            # everything after HALT is a no-op (state passes through unchanged)
            active = ~st.done
            st = jax.tree_util.tree_map(
                lambda n, o: jnp.where(active, n, o), new, st)
            return st, None

        def alu_branch(fn):
            def op(st, a, b, c):
                x, y, sel, braw = alu_operands(st, a, b, c)
                return set_reg(st, a, fn(x, y, sel, braw))
            return op

        branches = [None] * isa.N_OPCODES
        branches[isa.HALT] = op_halt
        branches[isa.READ] = op_read
        branches[isa.WRITE] = op_write
        for _opcode in isa.ALU_OPS:
            branches[_opcode] = alu_branch(alu_fns[_opcode])

        def step_switch(st: _VMState, row):
            op, a, b, c = row[0], row[1], row[2], row[3]
            op = jnp.where((op >= 0) & (op < isa.N_OPCODES), op, isa.HALT)
            new = jax.lax.switch(op, branches, st, a, b, c)
            active = ~st.done
            st = jax.tree_util.tree_map(
                lambda n, o: jnp.where(active, n, o), new, st)
            return st, None

        init = _VMState(
            regs=jnp.zeros((n_regs,), vdt),
            read_locs=jnp.full((R,), NO_LOC, jnp.int32),
            read_writer=jnp.full((R,), STORAGE, jnp.int32),
            read_inc=jnp.full((R,), -1, jnp.int32),
            write_locs=jnp.full((W,), NO_LOC, jnp.int32),
            write_vals=jnp.zeros((W,), vdt),
            r=jnp.asarray(0, jnp.int32), w=jnp.asarray(0, jnp.int32),
            blocked=jnp.asarray(False), blocker=jnp.asarray(-1, jnp.int32),
            done=jnp.asarray(False),
        )
        step = step_gather if self.dispatch == "gather" else step_switch
        st, _ = jax.lax.scan(step, init, code)
        # Slot overflow (more executed READ/WRITE ops than the engine config
        # provisions) would have clamped onto the last slot, dropping records
        # that validation needs.  SpecCtx raises at trace time; programs are
        # runtime data here, so fail loudly instead of silently: report the
        # incarnation blocked on ITSELF — an unresolvable dependency, so the
        # engine stalls to its wave cap and returns committed=False.
        overflow = (st.r > R) | (st.w > W)
        return ExecResult(
            read_locs=st.read_locs, read_writer=st.read_writer,
            read_inc=st.read_inc, write_locs=st.write_locs,
            write_vals=st.write_vals,
            blocked=st.blocked | overflow,
            blocker=jnp.where(overflow, txn_idx, st.blocker))

    # -- sequential oracle path ---------------------------------------------
    def __call__(self, p, ctx) -> None:
        """Interpret against a plain read/write context (e.g. ``OracleCtx``).

        Malformed operands are clamped exactly as in ``execute_spec`` so the
        two harnesses never diverge, even on hand-authored bytecode.
        """
        self._interp(p, ctx)

    def _interp(self, p, ctx) -> list:
        """``__call__`` body; returns the final register file (golden tests)."""
        code = np.asarray(p["code"])
        args = np.asarray(p["args"])
        regs = [0] * self.n_regs

        def cr(i):        # register operand, clamped like creg()
            return min(max(i, 0), self.n_regs - 1)

        def cp(i):        # param operand, clamped like the args gather
            return min(max(i, 0), args.shape[0] - 1)

        for op, a, b, c in code.tolist():
            if op == isa.HALT:
                break
            elif op == isa.LOAD_PARAM:
                regs[cr(a)] = int(args[cp(b)])
            elif op == isa.LOAD_IMM:
                regs[cr(a)] = int(b)
            elif op == isa.MOV:
                regs[cr(a)] = regs[cr(b)]
            elif op == isa.READ:
                en = True if c < 0 else regs[cr(c)] != 0
                v = ctx.read(regs[cr(b)] if en else NO_LOC, enabled=en)
                regs[cr(a)] = int(np.asarray(v)) if en else 0
            elif op == isa.WRITE:
                en = True if c < 0 else regs[cr(c)] != 0
                ctx.write(regs[cr(a)] if en else NO_LOC, regs[cr(b)],
                          enabled=en)
            elif op == isa.ADD:
                regs[cr(a)] = _i32(regs[cr(b)] + regs[cr(c)])
            elif op == isa.SUB:
                regs[cr(a)] = _i32(regs[cr(b)] - regs[cr(c)])
            elif op == isa.MUL:
                regs[cr(a)] = _i32(regs[cr(b)] * regs[cr(c)])
            elif op == isa.GE:
                regs[cr(a)] = int(regs[cr(b)] >= regs[cr(c)])
            elif op == isa.LE:
                regs[cr(a)] = int(regs[cr(b)] <= regs[cr(c)])
            elif op == isa.AND:
                regs[cr(a)] = int(regs[cr(b)] != 0 and regs[cr(c)] != 0)
            elif op == isa.SELECT:
                regs[cr(a)] = regs[cr(b)] if regs[cr(a)] != 0 else regs[cr(c)]
            elif op == isa.DIV:
                y = regs[cr(c)]
                regs[cr(a)] = 0 if y == 0 else _i32(regs[cr(b)] // y)
            elif op == isa.MOD:
                y = regs[cr(c)]
                regs[cr(a)] = 0 if y == 0 else _i32(regs[cr(b)] % y)
            elif op == isa.HASH:
                regs[cr(a)] = isa.hash_mix(regs[cr(b)], regs[cr(c)])
            else:
                break  # undefined opcode traps to HALT, as in execute_spec
        return regs


def _i32(x: int) -> int:
    """Wrap to int32 to match the JAX interpreter's register arithmetic."""
    return ((int(x) + 2**31) % 2**32) - 2**31

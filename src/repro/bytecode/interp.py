"""Bytecode interpreter: one ``lax.scan`` over ops, ``lax.switch`` dispatch.

:class:`BytecodeVM` runs a transaction whose *program is data* — the txn's
params carry ``code`` ``(L, 4)`` int32 and ``args`` ``(P,)`` int32 — inside
the same two harnesses as the Python DSL programs of :mod:`repro.core.vm`:

* ``execute_spec`` — speculative JAX execution in the wave engine.  It mirrors
  :class:`~repro.core.vm.SpecCtx` semantics exactly (read-own-write first,
  then the MV resolver; ESTIMATE hits set ``blocked``; latest-write-per-
  location dedup) but with *traced* slot counters, because slots are consumed
  by data-dependent READ/WRITE ops rather than static Python call sites.  The
  result is a standard :class:`~repro.core.types.ExecResult`, so dependency
  detection, validation, and the commit frontier are untouched.
* ``__call__(p, ctx)`` — plain-Python interpretation against
  :class:`~repro.core.vm.OracleCtx`, so ``run_sequential`` accepts a
  :class:`BytecodeVM` directly as the ground-truth reference.

Cost model: a wave executes ``window`` txns × ``L`` ops; under ``vmap`` the
``lax.switch`` lowers to computing every opcode's branch and selecting
per-lane — the standard price of SIMD-interpreting heterogeneous programs.
Branches are O(max_reads + max_writes) scalar work, so a wave is
O(window · L · (R + W)) plus one MV resolve per READ op.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.bytecode import isa
from repro.core.types import NO_LOC, STORAGE, EngineConfig, ExecResult


class _VMState(NamedTuple):
    """Scan carry: register file + the SpecCtx-equivalent record arrays."""

    regs: jax.Array          # (n_regs,) value_dtype
    read_locs: jax.Array     # (R,) i32
    read_writer: jax.Array   # (R,) i32
    read_inc: jax.Array      # (R,) i32
    write_locs: jax.Array    # (W,) i32
    write_vals: jax.Array    # (W,) value_dtype
    r: jax.Array             # () i32 next read slot
    w: jax.Array             # () i32 next write slot
    blocked: jax.Array       # () bool
    blocker: jax.Array       # () i32
    done: jax.Array          # () bool (HALT reached)


class BytecodeVM:
    """Interpreter for ``(code, args)`` transactions.

    ``n_regs`` is the static register-file size (>= max register index + 1
    across every program that may appear in a block).
    """

    def __init__(self, n_regs: int):
        if n_regs < 1:
            raise ValueError("n_regs must be >= 1")
        self.n_regs = n_regs

    # -- speculative path (wave engine) -------------------------------------
    def execute_spec(self, cfg: EngineConfig, txn_idx: jax.Array, resolver,
                     value_reader, p) -> ExecResult:
        code = jnp.asarray(p["code"], jnp.int32)
        args = jnp.asarray(p["args"], cfg.value_dtype)
        n_regs, R, W = self.n_regs, cfg.max_reads, cfg.max_writes
        vdt = cfg.value_dtype

        def creg(i):
            return jnp.clip(i, 0, n_regs - 1)

        def enab(st, c):
            return jnp.where(c < 0, True, st.regs[creg(c)] != 0)

        def set_reg(st, i, v):
            return st._replace(regs=st.regs.at[creg(i)].set(v.astype(vdt)))

        def op_halt(st, a, b, c):
            return st._replace(done=jnp.asarray(True))

        def op_load_param(st, a, b, c):
            return set_reg(st, a, args[jnp.clip(b, 0, args.shape[0] - 1)])

        def op_load_imm(st, a, b, c):
            return set_reg(st, a, b.astype(vdt))

        def op_mov(st, a, b, c):
            return set_reg(st, a, st.regs[creg(b)])

        def op_read(st, a, b, c):
            loc = st.regs[creg(b)].astype(jnp.int32)
            enabled = enab(st, c) & ~st.blocked
            eff_loc = jnp.where(enabled, loc, NO_LOC)
            # read-own-write: write-time dedup keeps at most one live match.
            own = st.write_locs == eff_loc
            own_hit = own.any()
            own_val = jnp.where(own, st.write_vals, 0).sum().astype(vdt)
            res = resolver(eff_loc, txn_idx)
            mv_val = value_reader(res, eff_loc)
            value = jnp.where(own_hit, own_val, mv_val)
            value = jnp.where(enabled, value, 0).astype(vdt)
            rec = enabled & ~own_hit
            slot = jnp.clip(st.r, 0, R - 1)
            st = st._replace(
                read_locs=st.read_locs.at[slot].set(
                    jnp.where(rec, eff_loc, NO_LOC)),
                read_writer=st.read_writer.at[slot].set(
                    jnp.where(rec & res.found, res.writer, STORAGE)),
                read_inc=st.read_inc.at[slot].set(
                    jnp.where(rec & res.found, res.inc, -1)),
                r=st.r + 1,
            )
            hit_est = rec & res.is_estimate & ~st.blocked
            st = st._replace(
                blocker=jnp.where(hit_est, res.writer, st.blocker),
                blocked=st.blocked | hit_est,
            )
            return set_reg(st, a, value)

        def op_write(st, a, b, c):
            loc = st.regs[creg(a)].astype(jnp.int32)
            value = st.regs[creg(b)]
            enabled = enab(st, c) & ~st.blocked
            # latest-value-per-location: kill earlier live slots on this loc.
            wlocs = jnp.where(enabled & (st.write_locs == loc), NO_LOC,
                              st.write_locs)
            slot = jnp.clip(st.w, 0, W - 1)
            return st._replace(
                write_locs=wlocs.at[slot].set(jnp.where(enabled, loc, NO_LOC)),
                write_vals=st.write_vals.at[slot].set(
                    jnp.where(enabled, value, 0).astype(vdt)),
                w=st.w + 1,
            )

        def alu(fn):
            def op(st, a, b, c):
                return set_reg(st, a, fn(st.regs[creg(b)], st.regs[creg(c)]))
            return op

        def op_select(st, a, b, c):
            cond = st.regs[creg(a)] != 0
            return set_reg(st, a, jnp.where(cond, st.regs[creg(b)],
                                            st.regs[creg(c)]))

        branches = [None] * isa.N_OPCODES
        branches[isa.HALT] = op_halt
        branches[isa.LOAD_PARAM] = op_load_param
        branches[isa.LOAD_IMM] = op_load_imm
        branches[isa.MOV] = op_mov
        branches[isa.READ] = op_read
        branches[isa.WRITE] = op_write
        branches[isa.ADD] = alu(lambda x, y: x + y)
        branches[isa.SUB] = alu(lambda x, y: x - y)
        branches[isa.MUL] = alu(lambda x, y: x * y)
        branches[isa.GE] = alu(lambda x, y: (x >= y).astype(vdt))
        branches[isa.LE] = alu(lambda x, y: (x <= y).astype(vdt))
        branches[isa.AND] = alu(lambda x, y: ((x != 0) & (y != 0)).astype(vdt))
        branches[isa.SELECT] = op_select

        def step(st: _VMState, row):
            op, a, b, c = row[0], row[1], row[2], row[3]
            # undefined opcode traps to HALT (never silently runs another op)
            op = jnp.where((op >= 0) & (op < isa.N_OPCODES), op, isa.HALT)
            new = jax.lax.switch(op, branches, st, a, b, c)
            # everything after HALT is a no-op (state passes through unchanged)
            active = ~st.done
            st = jax.tree_util.tree_map(
                lambda n, o: jnp.where(active, n, o), new, st)
            return st, None

        init = _VMState(
            regs=jnp.zeros((n_regs,), vdt),
            read_locs=jnp.full((R,), NO_LOC, jnp.int32),
            read_writer=jnp.full((R,), STORAGE, jnp.int32),
            read_inc=jnp.full((R,), -1, jnp.int32),
            write_locs=jnp.full((W,), NO_LOC, jnp.int32),
            write_vals=jnp.zeros((W,), vdt),
            r=jnp.asarray(0, jnp.int32), w=jnp.asarray(0, jnp.int32),
            blocked=jnp.asarray(False), blocker=jnp.asarray(-1, jnp.int32),
            done=jnp.asarray(False),
        )
        st, _ = jax.lax.scan(step, init, code)
        # Slot overflow (more executed READ/WRITE ops than the engine config
        # provisions) would have clamped onto the last slot, dropping records
        # that validation needs.  SpecCtx raises at trace time; programs are
        # runtime data here, so fail loudly instead of silently: report the
        # incarnation blocked on ITSELF — an unresolvable dependency, so the
        # engine stalls to its wave cap and returns committed=False.
        overflow = (st.r > R) | (st.w > W)
        return ExecResult(
            read_locs=st.read_locs, read_writer=st.read_writer,
            read_inc=st.read_inc, write_locs=st.write_locs,
            write_vals=st.write_vals,
            blocked=st.blocked | overflow,
            blocker=jnp.where(overflow, txn_idx, st.blocker))

    # -- sequential oracle path ---------------------------------------------
    def __call__(self, p, ctx) -> None:
        """Interpret against a plain read/write context (e.g. ``OracleCtx``).

        Malformed operands are clamped exactly as in ``execute_spec`` so the
        two harnesses never diverge, even on hand-authored bytecode.
        """
        import numpy as np
        code = np.asarray(p["code"])
        args = np.asarray(p["args"])
        regs = [0] * self.n_regs

        def cr(i):        # register operand, clamped like creg()
            return min(max(i, 0), self.n_regs - 1)

        def cp(i):        # param operand, clamped like the args gather
            return min(max(i, 0), args.shape[0] - 1)

        for op, a, b, c in code.tolist():
            if op == isa.HALT:
                break
            elif op == isa.LOAD_PARAM:
                regs[cr(a)] = int(args[cp(b)])
            elif op == isa.LOAD_IMM:
                regs[cr(a)] = int(b)
            elif op == isa.MOV:
                regs[cr(a)] = regs[cr(b)]
            elif op == isa.READ:
                en = True if c < 0 else regs[cr(c)] != 0
                v = ctx.read(regs[cr(b)] if en else NO_LOC, enabled=en)
                regs[cr(a)] = int(np.asarray(v)) if en else 0
            elif op == isa.WRITE:
                en = True if c < 0 else regs[cr(c)] != 0
                ctx.write(regs[cr(a)] if en else NO_LOC, regs[cr(b)],
                          enabled=en)
            elif op == isa.ADD:
                regs[cr(a)] = _i32(regs[cr(b)] + regs[cr(c)])
            elif op == isa.SUB:
                regs[cr(a)] = _i32(regs[cr(b)] - regs[cr(c)])
            elif op == isa.MUL:
                regs[cr(a)] = _i32(regs[cr(b)] * regs[cr(c)])
            elif op == isa.GE:
                regs[cr(a)] = int(regs[cr(b)] >= regs[cr(c)])
            elif op == isa.LE:
                regs[cr(a)] = int(regs[cr(b)] <= regs[cr(c)])
            elif op == isa.AND:
                regs[cr(a)] = int(regs[cr(b)] != 0 and regs[cr(c)] != 0)
            elif op == isa.SELECT:
                regs[cr(a)] = regs[cr(b)] if regs[cr(a)] != 0 else regs[cr(c)]
            else:
                break  # undefined opcode traps to HALT, as in execute_spec


def _i32(x: int) -> int:
    """Wrap to int32 to match the JAX interpreter's register arithmetic."""
    return ((int(x) + 2**31) % 2**32) - 2**31

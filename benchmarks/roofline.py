"""Roofline table generator: reads the dry-run JSON and renders
EXPERIMENTS.md §Roofline rows (also usable standalone).

  PYTHONPATH=src python -m benchmarks.roofline [--json benchmarks/results/dryrun.json]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.launch.drylib import HBM_BW, ICI_BW, PEAK_FLOPS


def load(path: str):
    with open(path) as f:
        return json.load(f)


def fmt_row(r: dict) -> str:
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status'].upper()} — {r['note'][:60]} | | | | | |")
    rf = r.get("roofline") or {}
    return ("| {arch} | {shape} | {mesh} | {c:.2e} | {m:.2e} | {k:.2e} | "
            "{bound} | {useful:.2f} | {frac:.3f} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                c=rf.get("compute_s", 0), m=rf.get("memory_s", 0),
                k=rf.get("collective_s", 0), bound=rf.get("bound", "?"),
                useful=rf.get("useful_flops_ratio", 0),
                frac=rf.get("roofline_fraction", 0)))


def render(results, mesh_filter=None) -> str:
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "bound | useful_flops | roofline_frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    key = lambda r: (r["arch"], r["shape"], r["mesh"])
    for r in sorted(results, key=key):
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        lines.append(fmt_row(r))
    return "\n".join(lines)


def summarize(results) -> dict:
    ok = [r for r in results if r["status"] == "ok"]
    worst = sorted((r for r in ok if r.get("roofline")),
                   key=lambda r: r["roofline"]["roofline_fraction"])
    coll = sorted((r for r in ok if r.get("roofline")),
                  key=lambda r: -r["roofline"]["collective_s"])
    return {
        "n_ok": len(ok),
        "n_skipped": sum(r["status"] == "skipped" for r in results),
        "n_failed": sum(r["status"] == "failed" for r in results),
        "worst_fraction": [(r["arch"], r["shape"], r["mesh"],
                            r["roofline"]["roofline_fraction"])
                           for r in worst[:5]],
        "most_collective_bound": [(r["arch"], r["shape"], r["mesh"],
                                   r["roofline"]["collective_s"])
                                  for r in coll[:5]],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="benchmarks/results/dryrun.json")
    args = ap.parse_args(argv)
    results = load(args.json)
    print(f"# hardware: {PEAK_FLOPS/1e12:.0f} TFLOP/s bf16, "
          f"{HBM_BW/1e9:.0f} GB/s HBM, {ICI_BW/1e9:.0f} GB/s ICI per chip")
    print(render(results))
    print()
    s = summarize(results)
    print(f"# {s['n_ok']} ok / {s['n_skipped']} skipped / "
          f"{s['n_failed']} failed")
    print("# worst roofline fractions:", s["worst_fraction"])
    print("# most collective-bound:", s["most_collective_bound"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

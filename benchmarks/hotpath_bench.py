"""Wave hot-loop phase benchmark: incremental MV update vs full rebuild.

Where ``engine_bench`` measures end-to-end block throughput, this suite opens
the wave loop up: it replays the engine's own phase functions
(``_execute_phase`` / ``_index_phase`` / ``_validate_all``) step by step in
Python — each phase jitted separately — and times every phase on every wave
of real contended executions over the PR 3 shard grid
(``n_locs × n_shards × zipf_s``).  On each wave state it times BOTH index
maintenance paths on identical inputs:

* ``build``  — ``backend.build(write_locs)``: the O(block) full lexsort the
  engine ran every wave before incremental maintenance existed;
* ``update`` — ``backend.update(...)`` on the wave's delta: the event merge
  (``window*W`` searches + one cumsum + two gathers), O(wave) sort work.

It also cross-checks the two paths byte-for-byte on every wave (the property
suite ``tests/test_mv_incremental.py`` is the real guarantee; the check here
pins the *benchmark* to measuring equivalent work) and records end-to-end
rebuild-vs-incremental engine throughput for the same blocks.

Output: ``BENCH_hotpath.json`` at the repo root — the perf trajectory
artifact CI uploads per commit.

  PYTHONPATH=src python -m benchmarks.hotpath_bench --fast
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks import registry as REG
from repro.core import mv
from repro.core import workloads as W
from repro.core import engine as E
from repro.core.engine import make_executor


def _timed_call(fn, *args, inner=1):
    """Best-of-``inner`` wall-clock for one jitted call (same args); the
    shared harness with the pre-warmed-phase convention (callers warm)."""
    return REG.timed(fn, args, reps=1, inner=inner, warm=False, check=None)


def _phase_fns(vm, params, storage, cfg):
    """The engine's wave loop as separately-jitted phase callables — what
    both the per-wave timing replay and the compiled-artifact cost table
    lower (one definition, so they measure/account the same programs)."""
    backend = mv.make_backend(cfg)

    @jax.jit
    def init():
        return E._init_state(cfg)

    @jax.jit
    def execute(state):
        return E._execute_phase(state, vm, params, storage, cfg)

    @jax.jit
    def index_update(index, write_locs, delta):
        return backend.update(index, write_locs, delta.txn_ids,
                              delta.old_write_locs, delta.new_write_locs)[0]

    @jax.jit
    def index_build(write_locs):
        return backend.build(write_locs)

    @jax.jit
    def record_reads(state, delta, index):
        state = state._replace(index=index)
        if E._skip_enabled(cfg):
            rrv = delta.ver0[backend.region_of(delta.read_locs)]
            state = state._replace(
                read_region_ver=state.read_region_ver.at[delta.txn_ids].set(
                    rrv, mode="drop"))
        return state

    @jax.jit
    def validate(state):
        return E._validate_all(state, cfg)._replace(wave=state.wave + 1)

    return dict(init=init, execute=execute, index_update=index_update,
                index_build=index_build, record_reads=record_reads,
                validate=validate)


def phase_timings(vm, params, storage, cfg, reps=3):
    """Per-wave phase wall-clock over a full block execution.

    Replays the engine loop with each phase as its own jitted function; every
    wave state is fed to BOTH index paths, so build-vs-update is an
    apples-to-apples comparison on identical inputs.  The index phases take
    exactly the arrays the engine hands the backend (not the whole
    EngineState), so per-call pytree dispatch overhead is the same small
    constant for both.  Returns per-phase medians (milliseconds) over all
    waves of ``reps`` replays.
    """
    fns = _phase_fns(vm, params, storage, cfg)
    init, execute = fns["init"], fns["execute"]
    index_update, index_build = fns["index_update"], fns["index_build"]
    record_reads, validate = fns["record_reads"], fns["validate"]

    # warm every phase once (compile outside the timed loop)
    state0, delta0 = execute(init())
    index0 = index_update(state0.index, state0.write_locs, delta0)
    jax.block_until_ready(validate(record_reads(state0, delta0, index0)))
    jax.block_until_ready(index_build(state0.write_locs))

    phases = {k: [] for k in ("execute", "update", "build", "validate")}
    waves = 0
    for _ in range(reps):
        state = init()
        waves = 0
        while bool(state.frontier < cfg.n_txns) and waves < cfg.waves_cap():
            (state, delta), t = _timed_call(execute, state)
            phases["execute"].append(t)
            built, t = _timed_call(index_build, state.write_locs, inner=3)
            phases["build"].append(t)
            index, t = _timed_call(index_update, state.index,
                                   state.write_locs, delta, inner=3)
            phases["update"].append(t)
            # the bench must be measuring equivalent work, every wave —
            # the full index (keys AND writer/slot packing AND offsets),
            # not just the key stream
            for field in ("keys", "packed", "starts"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(index, field)),
                    np.asarray(getattr(built, field)), err_msg=field)
            state = record_reads(state, delta, index)
            state, t = _timed_call(validate, state)
            phases["validate"].append(t)
            waves += 1
        assert bool(state.frontier >= cfg.n_txns), "block did not commit"
    return {k: float(np.median(v) * 1e3) for k, v in phases.items()}, waves


def end_to_end(vm, params, storage, cfg, reps=3):
    """Full jitted engine tps for one maintenance/validation variant."""
    run = make_executor(vm, cfg)
    res, t = REG.timed(run, (params, storage), reps=reps)
    return dict(tps=cfg.n_txns / t, waves=int(res.waves),
                execs=int(res.execs), val_aborts=int(res.val_aborts))


def phase_cost_table(vm, params, storage, cfg):
    """Compiled-artifact cost accounting for the wave loop's phases.

    Lowers the SAME jitted phase callables the timing replay executes and
    walks their post-compile HLO (trip-count-aware, see
    :mod:`repro.obs.cost`): FLOPs, HBM bytes, and the compiler's
    argument/output/temp memory per phase — so ``BENCH_hotpath.json``
    carries what each phase *is*, not only what it *took*."""
    from repro.obs import cost as C
    fns = _phase_fns(vm, params, storage, cfg)
    state0, delta0 = fns["execute"](fns["init"]())
    index0 = fns["index_update"](state0.index, state0.write_locs, delta0)
    state1 = fns["record_reads"](state0, delta0, index0)
    return C.phase_costs({
        "execute": (fns["execute"], state0),
        "update": (fns["index_update"], state0.index, state0.write_locs,
                   delta0),
        "build": (fns["index_build"], state0.write_locs),
        "validate": (fns["validate"], state1),
    })


def run_grid(n_txns=1024, reps=2, fast=True):
    """The PR 3 shard grid, hot-loop edition."""
    record = {"n_txns": n_txns, "backend": "sharded", "grid": {}}
    n_locs_axis = (10**5, 10**7)
    shards_axis = (4, 16) if fast else (1, 4, 16)
    for n_locs in n_locs_axis:
        for n_shards in shards_axis:
            for zipf_s in (0.0, 1.1):
                name = f"L{n_locs}_s{n_shards}_z{zipf_s}"
                try:
                    vm, params, storage, cfg = W.make_mixed_block(
                        W.MixedSpec(), n_txns, seed=7, n_locs=n_locs,
                        zipf_s=zipf_s, backend="sharded", n_shards=n_shards)
                except ValueError as e:       # 1 shard over 1e7: int32 refusal
                    record["grid"][name] = dict(error=str(e))
                    continue
                ph, waves = phase_timings(vm, params, storage, cfg, reps=reps)
                cell = dict(
                    waves=waves,
                    per_wave_ms=ph,
                    update_vs_build_x=ph["build"] / max(ph["update"], 1e-9),
                )
                inc = end_to_end(vm, params, storage, cfg, reps=reps)
                reb = end_to_end(vm, params, storage, dataclasses.replace(
                    cfg, mv_update="rebuild", dirty_validation=False),
                    reps=reps)
                cell["tps_incremental"] = inc["tps"]
                cell["tps_rebuild"] = reb["tps"]
                cell["tps_incremental_vs_rebuild_x"] = inc["tps"] / reb["tps"]
                # identical schedules: same waves/execs/abort counts
                assert (inc["waves"], inc["execs"], inc["val_aborts"]) == \
                    (reb["waves"], reb["execs"], reb["val_aborts"]), \
                    (name, inc, reb)
                record["grid"][name] = cell
                print(f"{name}: update {ph['update']:.3f}ms vs build "
                      f"{ph['build']:.3f}ms ({cell['update_vs_build_x']:.2f}x)"
                      f"  e2e {inc['tps']:.0f} vs {reb['tps']:.0f} tps "
                      f"({cell['tps_incremental_vs_rebuild_x']:.2f}x)")
    cells = [c for c in record["grid"].values() if "update_vs_build_x" in c]
    record["min_update_vs_build_x"] = min(c["update_vs_build_x"]
                                          for c in cells)
    record["median_update_vs_build_x"] = float(np.median(
        [c["update_vs_build_x"] for c in cells]))
    return record


# ---------------------------------------------------------------------------
# Registered suite
# ---------------------------------------------------------------------------

HOTPATH = REG.register_suite(
    "hotpath",
    doc="the wave loop opened up: per-phase timings over the shard grid "
        "with incremental MV update vs full rebuild on identical inputs, "
        "plus per-phase compiled-artifact cost accounting")

#: The representative cell the compiled-artifact cost table lowers — the
#: contended sharded config (1e5 locations, 16 shards, Zipf 1.1), present
#: in both --fast and --full grids.
COST_CELL_KW = dict(n_locs=10**5, n_shards=16, zipf_s=1.1)


@REG.register_benchmark(HOTPATH, "hot_loop_grid",
                        impls=("update", "rebuild"))
def _hotpath_grid(ctx):
    """Per-wave phase replay + end-to-end incremental-vs-rebuild over the
    n_locs x n_shards x zipf_s grid."""
    ctx.record.update(run_grid(n_txns=ctx.size(1024, 1024),
                               reps=int(ctx.params.get("reps", 2)),
                               fast=ctx.fast))


@REG.register_benchmark(HOTPATH, "phase_cost")
def _hotpath_phase_cost(ctx):
    """HLO-walked FLOPs/bytes + compiler memory analysis per phase for the
    representative contended cell (trace/compile time only)."""
    n_txns = ctx.size(1024, 1024)
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), n_txns, seed=7, backend="sharded", **COST_CELL_KW)
    ctx.record["cost_cell"] = \
        f"L{COST_CELL_KW['n_locs']}_s{COST_CELL_KW['n_shards']}" \
        f"_z{COST_CELL_KW['zipf_s']}"
    ctx.record["cost"] = phase_cost_table(vm, params, storage, cfg)


REG.register_metric(HOTPATH, "tps_incremental", scope="cell")
REG.register_metric(HOTPATH, "tps_rebuild", scope="cell")
REG.register_metric(HOTPATH, "update_vs_build_x", scope="cell")
REG.register_metric(HOTPATH, "median_update_vs_build_x", aggregate=True)
REG.register_metric(HOTPATH, "min_update_vs_build_x", aggregate=True)


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    ap.add_argument("--n-txns", type=int, default=1024)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the record here instead of the repo-root "
                    "BENCH_hotpath.json (CI regression checks write a "
                    "fresh record next to the committed baseline)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="additionally capture the grid under "
                    "jax.profiler.trace into DIR (perfetto dump; the "
                    "engine's blockstm.* named scopes label the phases)")
    args = ap.parse_args()
    kw = dict(fast=args.fast, out=args.out, n_txns=args.n_txns,
              reps=args.reps)
    if args.profile:
        from repro.obs.profile import profile_block
        with profile_block(args.profile):
            record, path = REG.run_suite("hotpath", **kw)
    else:
        record, path = REG.run_suite("hotpath", **kw)
    print(f"wrote {path}  (min update-vs-build "
          f"{record['min_update_vs_build_x']:.2f}x)")


if __name__ == "__main__":
    main()

"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * Block-STM engine benchmarks (paper Figs 3-8 analogues + backends)
  * model micro-benchmarks (per-arch reduced-config step wall-clock on CPU)
  * roofline summary (from the dry-run JSON if present)

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def bench_models(rows, steps=3):
    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, reduced_config
    from repro.models import model as MDL
    from repro.optim import adamw
    from repro.runtime import steps as RT

    for name in sorted(ARCHS):
        cfg = reduced_config(ARCHS[name])
        opt_cfg = adamw.AdamWConfig(total_steps=100)
        state = RT.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg,
                                    jnp.float32)
        batch = MDL.make_host_batch(cfg, batch=2, seq=32)
        step_fn = jax.jit(RT.make_train_step(cfg, opt_cfg))
        state, m = step_fn(state, batch)          # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = step_fn(state, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / steps
        rows.append((f"train_step_reduced_{name}", dt * 1e6,
                     f"loss={float(m['loss']):.3f}"))


def roofline_rows(rows):
    path = "benchmarks/results/dryrun.json"
    if not os.path.exists(path):
        rows.append(("roofline", 0.0, "dryrun.json missing - run "
                     "repro.launch.dryrun first"))
        return
    from benchmarks.roofline import load, summarize
    s = summarize(load(path))
    rows.append(("dryrun_cells_ok", float(s["n_ok"]),
                 f"skipped={s['n_skipped']};failed={s['n_failed']}"))
    for arch, shape, mesh, frac in s["worst_fraction"][:3]:
        rows.append((f"roofline_frac_{arch}_{shape}_{mesh}", frac, "worst-3"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    ap.add_argument("--skip-models", action="store_true")
    args = ap.parse_args()

    rows: list = []
    from benchmarks import engine_bench
    rows += engine_bench.run_all(fast=args.fast)
    if not args.skip_models:
        bench_models(rows)
    roofline_rows(rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()

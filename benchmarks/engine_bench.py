"""Block-STM engine benchmarks mirroring the paper's evaluation (§4.1).

One function per paper figure:
  Fig 3/6 -> bench_threads     (throughput vs #virtual threads, Diem & Aptos
                                read/write profiles, + Bohm-style baseline)
  Fig 4/7 -> bench_contention  (throughput vs #accounts: 2 / 10 / 100 / 1e3 / 1e4)
  Fig 5/8 -> bench_blocksize   (throughput vs block size)
  sequential baseline          (pure-Python sequential execution, the paper's
                                denominator; plus a jitted 1-window engine run)
  bytecode / mixed             (beyond-paper: interpreter overhead vs the
                                traced DSL, and heterogeneous blocks served by
                                ONE jitted executor with zero recompiles)
  baselines                    (the paper's comparison as a four-engine grid:
                                sequential / Block-STM / Bohm / LiTM on the
                                SAME heterogeneous mixed blocks through the
                                unified executor protocol, swept over conflict
                                rate × contract mix; plus the branch-free-ALU
                                vs ``lax.switch`` interpreter A/B)

CPU wall-clock replaces the paper's 32-core Rust numbers; the comparable
quantities are the *shapes* of the curves and the abort/incarnation
statistics, which are hardware-independent.  Results go to CSV; the bytecode
suites additionally emit ``BENCH_bytecode.json`` / ``BENCH_baselines.json``
perf records at the repo root (tps + recompile counts).

  PYTHONPATH=src python -m benchmarks.engine_bench --workload baselines --fast
"""
from __future__ import annotations

import os
import sys
import time

# --devices N env-var contract: XLA fixes the host platform's device count
# when the backend initializes, i.e. at first jax use — so the forced count
# must be in XLA_FLAGS BEFORE `import jax` below.  This is the same contract
# launch/dryrun.py satisfies by setting XLA_FLAGS at module line one; here
# the flag value comes from argv, so it is peeked pre-import (argparse runs
# far too late).  An already-forced count in the environment wins.
for _i, _a in enumerate(sys.argv):
    if _a == "--devices" and _i + 1 < len(sys.argv):
        _v = sys.argv[_i + 1]
    elif _a.startswith("--devices="):
        _v = _a.split("=", 1)[1]
    else:
        continue
    if not _v.isdigit():
        break       # malformed: fall through and let argparse report it
    if int(_v) > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_v}").strip()
    break

import jax  # noqa: E402  (after the forced-device-count env handling)
import numpy as np  # noqa: E402

from benchmarks import registry as REG
from repro.core import workloads as W
from repro.core.engine import make_executor
from repro.core.vm import run_sequential

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

DIEM = dict(cfg_reads=W.CHAIN_CFG_READS_DIEM)      # 21 reads / 4 writes
APTOS = dict(cfg_reads=W.CHAIN_CFG_READS_APTOS)    # 8 reads / 5 writes

# One shared block size per mode for the four-engine grid, so
# BENCH_baselines.json is comparable no matter which CLI path produced it
# (LiTM is O(n^2) under contention, hence smaller than the single-engine
# suites' FAST_N/FULL_N below).
BASELINES_FAST_N, BASELINES_FULL_N = 192, 512


# --devices N (0 = off): run the Block-STM engine cells multi-device — the
# sharded backend's regions placed across an N-device 'regions' mesh
# (repro.core.dist).  Set from the CLI in main().
_DEVICES = 0


def _dist_cfg_kw():
    """EngineConfig extras for the --devices mesh (empty when off)."""
    if _DEVICES <= 0:
        return {}
    from repro.launch.mesh import make_mesh
    return dict(dist=True, mesh=make_mesh("regions", (_DEVICES,)))


def _run_engine(spec, n_txns, window, seed=0, reps=3, backend="sorted",
                validation_window=0, **cfg_kw):
    if _DEVICES > 0:
        backend = "sharded"              # the only backend with regions
        cfg_kw = {**cfg_kw, **_dist_cfg_kw()}
    cfg = W.p2p_engine_config(spec, n_txns, window=window, backend=backend,
                              validation_window=validation_window, **cfg_kw)
    run = make_executor(W.p2p_program(spec), cfg)
    # Fresh block per rep (the harness owns warmup + the committed assert).
    res, t = REG.timed_blocks(
        run, lambda r: W.make_p2p_block(spec, n_txns, seed=seed + r),
        reps=reps)
    return dict(tps=n_txns / t, seconds=t, waves=int(res.waves),
                execs=int(res.execs),
                dep_aborts=int(res.dep_aborts), val_aborts=int(res.val_aborts))


def _run_sequential(spec, n_txns, seed=0):
    params, storage = W.make_p2p_block(spec, n_txns, seed=seed)
    t0 = time.perf_counter()
    run_sequential(W.p2p_program(spec), params, storage, n_txns)
    t = time.perf_counter() - t0
    return dict(tps=n_txns / t, seconds=t)


def _run_bohm(spec, n_txns, window, seed=0):
    """Bohm [21] with perfect write sets (real implementation,
    core/baselines.py): dependency-exact fork-join schedule, zero wasted
    executions.  Write-set extraction (the information the paper grants Bohm
    'artificially') is excluded from the timing, as in the paper."""
    from repro.core import baselines as B
    cfg = W.p2p_engine_config(spec, n_txns, window=window)
    params, storage = W.make_p2p_block(spec, n_txns, seed=seed)
    pws = B.perfect_write_sets(W.p2p_program(spec), params, storage, cfg)
    run = B.make_baseline_executor("bohm", W.p2p_program(spec), cfg)
    _, t = REG.timed(run, (params, storage, pws), reps=1)
    return dict(tps=n_txns / t, seconds=t)


def _run_litm(spec, n_txns, seed=0):
    """LiTM [52]-style deterministic STM rounds (core/baselines.py)."""
    from repro.core import baselines as B
    cfg = W.p2p_engine_config(spec, n_txns)
    params, storage = W.make_p2p_block(spec, n_txns, seed=seed)
    run = B.make_baseline_executor("litm", W.p2p_program(spec), cfg)
    res, t = REG.timed(run, (params, storage), reps=1)
    return dict(tps=n_txns / t, seconds=t, execs=int(res.execs))


def bench_threads(rows, profile_name, profile, n_txns=1000, accounts=1000):
    spec = W.P2PSpec(n_accounts=accounts, **profile)
    seq = _run_sequential(spec, n_txns)
    rows.append((f"fig3_{profile_name}_seq", seq["seconds"] * 1e6 / n_txns,
                 f"tps={seq['tps']:.0f}"))
    for vthreads in (1, 2, 4, 8, 16, 32):
        r = _run_engine(spec, n_txns, window=vthreads)
        rows.append((f"fig3_{profile_name}_bstm_t{vthreads}",
                     r["seconds"] * 1e6 / n_txns,
                     f"tps={r['tps']:.0f};speedup={r['tps']/seq['tps']:.2f};"
                     f"execs={r['execs']};waves={r['waves']}"))
    b = _run_bohm(spec, n_txns, window=32)
    rows.append((f"fig3_{profile_name}_bohm_t32", b["seconds"] * 1e6 / n_txns,
                 f"tps={b['tps']:.0f}"))
    l = _run_litm(spec, n_txns)
    rows.append((f"fig3_{profile_name}_litm", l["seconds"] * 1e6 / n_txns,
                 f"tps={l['tps']:.0f};execs={l['execs']}"))


def bench_contention(rows, profile_name, profile, n_txns=1000):
    for accounts in (2, 10, 100, 1000, 10000):
        spec = W.P2PSpec(n_accounts=accounts, **profile)
        seq = _run_sequential(spec, n_txns)
        r = _run_engine(spec, n_txns, window=32)
        rows.append((f"fig4_{profile_name}_acc{accounts}",
                     r["seconds"] * 1e6 / n_txns,
                     f"tps={r['tps']:.0f};seq_tps={seq['tps']:.0f};"
                     f"speedup={r['tps']/seq['tps']:.2f};"
                     f"execs_per_txn={r['execs']/n_txns:.2f};"
                     f"val_aborts={r['val_aborts']}"))
        # beyond-paper optimized variant (§Perf): windowed validation,
        # dense MV backend when the location universe is tiny (<=64 locs;
        # measured crossover — at L~200 the per-wave dense table rebuild
        # costs more than the sort it replaces).  Under --devices every
        # cell runs sharded+dist; keep the reported label honest.
        backend = "sharded" if _DEVICES > 0 else \
            ("dense" if spec.n_locs <= 64 else "sorted")
        o = _run_engine(spec, n_txns, window=32, validation_window=128,
                        backend=backend)
        rows.append((f"fig4_{profile_name}_acc{accounts}_opt",
                     o["seconds"] * 1e6 / n_txns,
                     f"tps={o['tps']:.0f};speedup={o['tps']/seq['tps']:.2f};"
                     f"vs_base={o['tps']/r['tps']:.2f}x;backend={backend}"))


def bench_blocksize(rows, profile_name, profile, accounts=1000):
    for n_txns in (100, 1000, 5000, 10000):
        spec = W.P2PSpec(n_accounts=accounts, **profile)
        r = _run_engine(spec, n_txns, window=32, reps=2)
        rows.append((f"fig5_{profile_name}_n{n_txns}",
                     r["seconds"] * 1e6 / n_txns,
                     f"tps={r['tps']:.0f};waves={r['waves']}"))
        # optimized: window scales with block size + windowed validation
        w = max(32, min(256, n_txns // 64))
        o = _run_engine(spec, n_txns, window=w, validation_window=4 * w,
                        reps=2)
        rows.append((f"fig5_{profile_name}_n{n_txns}_opt",
                     o["seconds"] * 1e6 / n_txns,
                     f"tps={o['tps']:.0f};waves={o['waves']};window={w};"
                     f"vs_base={o['tps']/r['tps']:.2f}x"))


def bench_backends(rows, n_txns=512, accounts=200):
    if _DEVICES > 0:
        # --devices forces every engine cell onto the sharded dist config;
        # a sorted-vs-dense comparison would be two identical measurements
        # wearing different labels.
        rows.append(("backend_comparison_skipped", 0.0,
                     f"--devices {_DEVICES} forces backend=sharded"))
        return
    for backend in ("sorted", "dense"):
        spec = W.P2PSpec(n_accounts=accounts)
        r = _run_engine(spec, n_txns, window=32, backend=backend)
        rows.append((f"backend_{backend}", r["seconds"] * 1e6 / n_txns,
                     f"tps={r['tps']:.0f}"))


# ---------------------------------------------------------------------------
# Bytecode VM suites (beyond paper: programs as data, compile-once serving)
# ---------------------------------------------------------------------------

def _run_bytecode_p2p(spec, n_txns, window, seed=0, reps=3,
                      dispatch="gather"):
    """Homogeneous p2p block through the bytecode interpreter: isolates the
    interpretation overhead vs the traced DSL (same engine, same schedule)."""
    from repro.bytecode import compile as BC
    prog = BC.compile_p2p(spec)
    vm, cfg = BC.vm_and_config([prog], n_txns, spec.n_locs, window=window,
                               dispatch=dispatch)
    run = make_executor(vm, cfg)

    def block(s):
        params, storage = W.make_p2p_block(spec, n_txns, seed=s)
        args = BC.pack_args({k: np.asarray(v) for k, v in params.items()},
                            BC.P2P_ARGS, prog.n_params)
        return BC.homogeneous_block_params(prog, args), storage

    res, t = REG.timed_blocks(run, lambda r: block(seed + r), reps=reps)
    return dict(tps=n_txns / t, seconds=t, waves=int(res.waves),
                execs=int(res.execs), ops=int(prog.code.shape[0]))


def bench_bytecode(rows, n_txns=512, accounts=1000, record=None):
    """Traced-DSL p2p vs bytecode p2p: the cost of programs-as-data."""
    spec = W.P2PSpec(n_accounts=accounts)
    dsl = _run_engine(spec, n_txns, window=32)
    bc = _run_bytecode_p2p(spec, n_txns, window=32)
    rows.append(("bytecode_p2p_dsl", dsl["seconds"] * 1e6 / n_txns,
                 f"tps={dsl['tps']:.0f}"))
    rows.append(("bytecode_p2p_interp", bc["seconds"] * 1e6 / n_txns,
                 f"tps={bc['tps']:.0f};ops={bc['ops']};"
                 f"overhead={dsl['tps']/bc['tps']:.2f}x"))
    if record is not None:
        record["p2p_dsl_tps"] = dsl["tps"]
        record["p2p_bytecode_tps"] = bc["tps"]
        record["interp_overhead_x"] = dsl["tps"] / bc["tps"]


def bench_alu(rows, n_txns=512, accounts=1000, record=None):
    """Interpreter fast-path A/B: branch-free gather/select ALU (default)
    vs the legacy one-``lax.switch``-branch-per-opcode dispatch, on identical
    homogeneous p2p bytecode blocks (same engine, same schedule)."""
    spec = W.P2PSpec(n_accounts=accounts)
    r = {}
    for dispatch in ("switch", "gather"):
        r[dispatch] = _run_bytecode_p2p(spec, n_txns, window=32, reps=5,
                                        dispatch=dispatch)
        rows.append((f"alu_{dispatch}", r[dispatch]["seconds"] * 1e6 / n_txns,
                     f"tps={r[dispatch]['tps']:.0f}"))
    speedup = r["switch"]["seconds"] / r["gather"]["seconds"]
    rows.append(("alu_gather_speedup", speedup,
                 f"branch_free_vs_switch={speedup:.2f}x"))
    if record is not None:
        record["alu_n_txns"] = n_txns
        record["alu_switch_tps"] = r["switch"]["tps"]
        record["alu_gather_tps"] = r["gather"]["tps"]
        record["alu_gather_speedup_x"] = speedup
    return record


# ---------------------------------------------------------------------------
# Sharded MV backend grid: universe size × shard count × Zipf skew
# ---------------------------------------------------------------------------

def bench_shards(rows, n_txns=256, reps=2, record=None):
    """Throughput over ``n_locs × n_shards × zipf_s`` under the sharded MV
    backend (``repro.core.mv.sharded``).

    The 1e7 column is the headline: at this block size the flat int32 keys
    genuinely overflow (``1e7*(256+1) ≈ 2.57e9 > 2^31`` — the ``sorted`` and
    ``dense`` backends refuse the config), so only sharding reaches it.
    ``zipf_s`` shows contention governed by hotness (skew) rather than
    universe size — at 1e7 uniform locations conflicts vanish; at ``s=1.1``
    the hot head keeps the engine honest.  One executor per
    (n_locs, n_shards) cell serves both skew settings (zero recompiles,
    asserted via the jit cache).
    """
    assert 10**7 * (n_txns + 1) + n_txns >= 2**31, \
        "headline claim needs the 1e7 column beyond the flat int32 key bound"
    grid = {}
    cache_misses = 0
    for n_locs in (10**3, 10**5, 10**7):
        for n_shards in (1, 4, 16):
            run = None
            for zipf_s in (0.0, 1.1):
                try:
                    vm, params, storage, cfg = W.make_mixed_block(
                        W.MixedSpec(), n_txns, seed=7, n_locs=n_locs,
                        zipf_s=zipf_s, backend="sharded", n_shards=n_shards,
                        **_dist_cfg_kw())
                except ValueError as e:
                    # e.g. 1 shard over 1e7 locations: shard-local keys are
                    # the flat keys, and those overflow — the cell IS the
                    # demonstration, so record the refusal.
                    grid[f"L{n_locs}_s{n_shards}_z{zipf_s}"] = dict(
                        error=str(e))
                    rows.append((f"shards_L{n_locs}_s{n_shards}_z{zipf_s}",
                                 0.0, "int32_overflow_refused"))
                    continue
                if run is None:   # shapes/cfg identical across skew settings
                    run = make_executor(vm, cfg)
                res, t = REG.timed(run, (params, storage), reps=reps)
                cell = dict(tps=n_txns / t, waves=int(res.waves),
                            execs=int(res.execs),
                            val_aborts=int(res.val_aborts))
                grid[f"L{n_locs}_s{n_shards}_z{zipf_s}"] = cell
                rows.append((f"shards_L{n_locs}_s{n_shards}_z{zipf_s}",
                             t * 1e6 / n_txns,
                             f"tps={cell['tps']:.0f};waves={cell['waves']};"
                             f"execs={cell['execs']}"))
            if run is not None:
                # One executor serves both skew settings; any recompile is
                # a gated regression (jit_cache_misses, direction exact).
                cache_misses += run._cache_size() - 1
    if record is not None:
        record["n_txns"] = n_txns
        record["backend"] = "sharded"
        record["jit_cache_misses"] = cache_misses
        record["grid"] = grid


# ---------------------------------------------------------------------------
# Four-engine comparison grid (paper §4.1 on mixed blocks, unified protocol)
# ---------------------------------------------------------------------------

def _baseline_mixed_spec(contention, ratios):
    """Conflict rate via shared-universe size (paper Fig. 4's axis)."""
    if contention == "high":
        return W.MixedSpec(
            p2p=W.P2PSpec(n_accounts=8),
            indirect=W.IndirectSpec(n_slots=8),
            admission=W.AdmissionSpec(n_tenants=2, n_groups=4,
                                      total_pages=10**6,
                                      quota_per_tenant=10**6),
            ratios=ratios)
    return W.MixedSpec(
        p2p=W.P2PSpec(n_accounts=1000),
        indirect=W.IndirectSpec(n_slots=500),
        admission=W.AdmissionSpec(n_tenants=16, n_groups=64,
                                  total_pages=10**6, quota_per_tenant=10**5),
        ratios=ratios)


def bench_baselines(rows, n_txns=BASELINES_FAST_N, reps=2, record=None):
    """The paper's comparison, finally on our richest workload: sequential /
    Block-STM / Bohm / LiTM over conflict rate × contract mix, all four
    engines executing the SAME heterogeneous bytecode blocks through the
    unified executor protocol.  Per contention level each engine compiles
    once and serves every mix (the compile-once property now covers the
    baselines too)."""
    from repro.core import baselines as B
    mixes = [("even", (1, 1, 1)), ("p2p_heavy", (8, 1, 1)),
             ("admission_heavy", (1, 1, 8))]
    grid = {}
    for contention in ("high", "low"):
        vm, params, storage, cfg = W.make_mixed_block(
            _baseline_mixed_spec(contention, mixes[0][1]), n_txns, seed=0)
        run_bstm = make_executor(vm, cfg)
        run_bohm = B.make_baseline_executor("bohm", vm, cfg)
        run_litm = B.make_baseline_executor("litm", vm, cfg)
        for mname, ratios in mixes:
            _, params, storage, _ = W.make_mixed_block(
                _baseline_mixed_spec(contention, ratios), n_txns, seed=7)
            pws = B.perfect_write_sets(vm, params, storage, cfg)
            t0 = time.perf_counter()
            run_sequential(vm, params, storage, n_txns)
            seq_t = time.perf_counter() - t0
            cell = {"sequential": dict(tps=n_txns / seq_t)}
            for ename, fn, fargs in (
                    ("blockstm", run_bstm, (params, storage)),
                    ("bohm", run_bohm, (params, storage, pws)),
                    ("litm", run_litm, (params, storage))):
                res, t = REG.timed(fn, fargs, reps=reps)
                cell[ename] = dict(tps=n_txns / t, execs=int(res.execs))
            grid[f"{contention}_{mname}"] = cell
            rows.append((f"baselines_{contention}_{mname}",
                         cell["blockstm"]["tps"],
                         ";".join(f"{e}_tps={c['tps']:.0f}"
                                  for e, c in cell.items())))
    if record is not None:
        record["grid_n_txns"] = n_txns
        record["grid"] = grid


def bench_mixed(rows, n_txns=512, reps=3, record=None):
    """Heterogeneous blocks: one jitted executor across contract mixes.

    The headline property is the recompile count: every mix (and every seed)
    reuses the single compiled program — the compile-once serving path.
    """
    mixes = [("even", (1, 1, 1)), ("p2p_heavy", (8, 1, 1)),
             ("indirect_heavy", (1, 8, 1)), ("admission_heavy", (1, 1, 8))]
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(ratios=mixes[0][1]), n_txns, seed=0)
    run = make_executor(vm, cfg)
    res = run(params, storage)                       # the one and only compile
    res.snapshot.block_until_ready()
    mix_stats = {}
    for i, (name, ratios) in enumerate(mixes):
        def block(r, _i=i, _ratios=ratios):
            seed = (100 + _i) if r == 0 else 200 + 10 * _i + (r - 1)
            _, params, storage, _ = W.make_mixed_block(
                W.MixedSpec(ratios=_ratios), n_txns, seed=seed)
            return params, storage
        res, t = REG.timed_blocks(run, block, reps=reps)
        params, storage = block(reps)   # the last timed block, for seq
        seq_t0 = time.perf_counter()
        run_sequential(vm, params, storage, n_txns)
        seq_t = time.perf_counter() - seq_t0
        rows.append((f"mixed_{name}", t * 1e6 / n_txns,
                     f"tps={n_txns/t:.0f};waves={int(res.waves)};"
                     f"execs={int(res.execs)};seq_tps={n_txns/seq_t:.0f}"))
        mix_stats[name] = dict(tps=n_txns / t, waves=int(res.waves),
                               execs=int(res.execs), seq_tps=n_txns / seq_t)
    cache = run._cache_size() if hasattr(run, "_cache_size") else None
    rows.append(("mixed_recompiles", float(cache or 0),
                 f"jit_cache_entries={cache} (1 = zero re-jits across "
                 f"{len(mixes)} mixes)"))
    if record is not None:
        from repro.obs import cost as C
        record["n_txns"] = n_txns
        record["mixes"] = mix_stats
        record["jit_cache_entries"] = cache
        record["recompiles_after_first"] = (cache - 1) if cache else None
        # -1 would mean the executor stopped exposing its jit cache — as
        # loud a gate failure as an actual recompile.
        record["jit_cache_misses"] = C.cache_misses(run, expected_compiles=1)


def emit_trace(n_txns, trace_level=2):
    """--trace: run one traced mixed block and write WAVE_TRACE.json +
    CHROME_TRACE.json at the repo root (level-2 buffers: counters + abort
    edges).  Respects --devices (the trace then carries per-device
    mv_entries / dirty_regions rows).  Render with ``make report``."""
    import dataclasses

    from repro.obs import export as X
    from repro.obs import report as R

    kw = dict(backend="sharded", n_shards=16, **_dist_cfg_kw()) \
        if _DEVICES > 0 else {}
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), n_txns, seed=7, **kw)
    cfg = dataclasses.replace(cfg, trace_level=trace_level)
    res = make_executor(vm, cfg)(params, storage)
    assert bool(res.committed)
    meta = dict(workload="mixed", n_txns=n_txns, trace_level=trace_level,
                backend=cfg.backend, devices=max(_DEVICES, 1))
    d = X.write_wave_trace(os.path.join(_REPO_ROOT, "WAVE_TRACE.json"),
                           res.trace, res.waves, meta=meta)
    X.write_chrome_trace(os.path.join(_REPO_ROOT, "CHROME_TRACE.json"), d)
    print(R.summary(d))
    print("wrote WAVE_TRACE.json + CHROME_TRACE.json "
          "(report: make report; view: https://ui.perfetto.dev)")


def chaos_smoke(n_txns, seed=7):
    """--chaos: one mixed block under a full ChaosConfig schedule — the
    cheap end-to-end sanity leg CI runs on every commit (the exhaustive
    seed×backend×mesh grid lives in tests/test_guard.py; the overhead
    numbers in benchmarks/guard_bench.py).  Asserts the chaos run commits
    the byte-identical snapshot and prints the schedule-inflation stats."""
    import dataclasses

    from repro.guard import ChaosConfig

    kw = dict(backend="sharded", n_shards=16, **_dist_cfg_kw()) \
        if _DEVICES > 0 else {}
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), n_txns, seed=7, **kw)
    ref = make_executor(vm, cfg)(params, storage)
    assert bool(ref.committed)
    ccfg = dataclasses.replace(cfg, chaos=ChaosConfig(seed=seed))
    res = make_executor(vm, ccfg)(params, storage)
    assert bool(res.committed), "chaos run failed to commit"
    np.testing.assert_array_equal(np.asarray(res.snapshot),
                                  np.asarray(ref.snapshot))
    print(f"chaos smoke OK: snapshot byte-identical; waves "
          f"{int(ref.waves)} -> {int(res.waves)}, execs "
          f"{int(ref.execs)} -> {int(res.execs)}, val_aborts "
          f"{int(ref.val_aborts)} -> {int(res.val_aborts)}")


# One shared block size per mode, so BENCH_bytecode.json is comparable no
# matter which CLI path produced it.
FAST_N, FULL_N = 512, 1000


# ---------------------------------------------------------------------------
# Registered suites: bytecode / baselines / shards
# ---------------------------------------------------------------------------
# The bench_* functions above are the measurements; the registrations below
# are the contract — which A/Bs exist, which record fields are gated, and in
# which direction.  benchmarks.check_regression walks these declarations.

BYTECODE = REG.register_suite(
    "bytecode",
    doc="programs-as-data: traced-DSL vs bytecode-interpreter p2p, the "
        "branch-free gather ALU vs lax.switch dispatch, and compile-once "
        "serving of heterogeneous mixes")

BASELINES = REG.register_suite(
    "baselines",
    doc="the paper's four-engine comparison (sequential / Block-STM / Bohm "
        "/ LiTM) on identical heterogeneous bytecode blocks, over "
        "contention x contract mix")

SHARDS = REG.register_suite(
    "shards",
    doc="sharded MV backend grid: universe size x shard count x Zipf skew "
        "(the 1e7-location column only sharding reaches)")


@REG.register_benchmark(BYTECODE, "dsl_vs_interp", impls=("dsl", "interp"))
def _bytecode_dsl_vs_interp(ctx):
    """Interpretation overhead: identical p2p blocks through the traced DSL
    and the bytecode VM (same engine, same schedule)."""
    bench_bytecode(ctx.rows, n_txns=ctx.size(FAST_N, FULL_N),
                   record=ctx.record)


@REG.register_benchmark(BYTECODE, "alu", impls=("switch", "gather"))
def _bytecode_alu(ctx):
    """Interpreter dispatch A/B: branch-free gather/select ALU vs one
    lax.switch branch per opcode."""
    bench_alu(ctx.rows, n_txns=ctx.size(FAST_N, FULL_N), record=ctx.record)


@REG.register_benchmark(BYTECODE, "mixed_compile_once")
def _bytecode_mixed(ctx):
    """One jitted executor across contract mixes; the gated headline is
    jit_cache_misses == 0."""
    bench_mixed(ctx.rows, n_txns=ctx.size(FAST_N, FULL_N), record=ctx.record)


REG.register_metric(BYTECODE, "p2p_dsl_tps")
REG.register_metric(BYTECODE, "p2p_bytecode_tps")
REG.register_metric(BYTECODE, "interp_overhead_x", direction="lower")
REG.register_metric(BYTECODE, "alu_switch_tps")
REG.register_metric(BYTECODE, "alu_gather_tps")
REG.register_metric(BYTECODE, "alu_gather_speedup_x")
REG.register_metric(BYTECODE, "jit_cache_misses", direction="exact")


@REG.register_benchmark(BASELINES, "four_engines",
                        impls=("sequential", "blockstm", "bohm", "litm"))
def _baselines_four_engines(ctx):
    """All four engines on the SAME blocks through the unified executor
    protocol (paper §4.1's comparison on our richest workload)."""
    bench_baselines(ctx.rows,
                    n_txns=ctx.size(BASELINES_FAST_N, BASELINES_FULL_N),
                    record=ctx.record)


@REG.register_benchmark(BASELINES, "alu", impls=("switch", "gather"))
def _baselines_alu(ctx):
    """The ALU A/B rides along so BENCH_baselines.json keeps carrying the
    branch-free-dispatch headline."""
    bench_alu(ctx.rows, n_txns=ctx.size(FAST_N, FULL_N, key="alu_n_txns"),
              record=ctx.record)


REG.register_metric(BASELINES, "sequential.tps", scope="cell")
REG.register_metric(BASELINES, "blockstm.tps", scope="cell")
REG.register_metric(BASELINES, "bohm.tps", scope="cell")
REG.register_metric(BASELINES, "litm.tps", scope="cell")
REG.register_metric(BASELINES, "alu_gather_tps")
REG.register_metric(BASELINES, "alu_gather_speedup_x")


@REG.register_benchmark(SHARDS, "shard_grid")
def _shards_grid(ctx):
    """n_locs x n_shards x zipf_s grid under the sharded MV backend,
    including the recorded int32-overflow refusals."""
    bench_shards(ctx.rows, n_txns=ctx.size(256, 256), record=ctx.record)


REG.register_metric(SHARDS, "tps", scope="cell")
# Schedule shape is deterministic at fixed seed/params: any waves/execs
# drift between comparable runs is a semantics change, not noise.
REG.register_metric(SHARDS, "waves", scope="cell", direction="exact")
REG.register_metric(SHARDS, "execs", scope="cell", direction="exact")
REG.register_metric(SHARDS, "jit_cache_misses", direction="exact")


def run_all(fast: bool = True):
    rows: list = []
    profiles = [("aptos", APTOS), ("diem", DIEM)]
    n = FAST_N if fast else FULL_N
    for name, prof in profiles:
        bench_threads(rows, name, prof, n_txns=n)
        bench_contention(rows, name, prof, n_txns=n)
    bench_blocksize(rows, "aptos", APTOS)
    bench_backends(rows)
    REG.run_suite("bytecode", fast=fast, rows=rows)
    REG.run_suite("baselines", fast=fast, rows=rows)
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", default="all",
                    choices=["all", "p2p", "mixed", "bytecode", "baselines",
                             "shards"])
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="run engine cells multi-device over an N-device "
                    "'regions' mesh (forces the host platform device count "
                    "— handled before jax import, see module docstring)")
    ap.add_argument("--trace", action="store_true",
                    help="additionally run one trace_level=2 mixed block "
                    "and write WAVE_TRACE.json + CHROME_TRACE.json "
                    "(see repro.obs)")
    ap.add_argument("--chaos", action="store_true",
                    help="additionally run one mixed block under a chaos "
                    "schedule and assert the committed snapshot is "
                    "byte-identical (see repro.guard)")
    args = ap.parse_args()
    global _DEVICES
    _DEVICES = args.devices
    if _DEVICES > len(jax.devices()):
        raise SystemExit(
            f"--devices {_DEVICES}: only {len(jax.devices())} devices "
            f"visible; XLA_FLAGS was already set without a forced host "
            f"platform device count >= {_DEVICES}")

    rows: list = []
    n = FAST_N if args.fast else FULL_N
    if args.workload == "all":
        rows = run_all(fast=args.fast)
    elif args.workload == "p2p":
        bench_threads(rows, "aptos", APTOS, n_txns=n)
    elif args.workload == "mixed":
        # Smoke leg (CI's --trace/--chaos carrier): runs the compile-once
        # mix bench alone, WITHOUT emitting a record — a partial
        # BENCH_bytecode.json would clobber the committed baseline.  The
        # full suite is `--workload bytecode` (or benchmarks.registry).
        bench_mixed(rows, n_txns=n)
    else:
        # bytecode / baselines / shards are registered suites: the registry
        # harness emits the record and appends the history line.
        REG.run_suite(args.workload, fast=args.fast, rows=rows)

    if args.trace:
        emit_trace(n, trace_level=2)
    if args.chaos:
        chaos_smoke(n)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()

"""Block-STM engine benchmarks mirroring the paper's evaluation (§4.1).

One function per paper figure:
  Fig 3/6 -> bench_threads     (throughput vs #virtual threads, Diem & Aptos
                                read/write profiles, + Bohm-style baseline)
  Fig 4/7 -> bench_contention  (throughput vs #accounts: 2 / 10 / 100 / 1e3 / 1e4)
  Fig 5/8 -> bench_blocksize   (throughput vs block size)
  sequential baseline          (pure-Python sequential execution, the paper's
                                denominator; plus a jitted 1-window engine run)

CPU wall-clock replaces the paper's 32-core Rust numbers; the comparable
quantities are the *shapes* of the curves and the abort/incarnation
statistics, which are hardware-independent.  Results go to CSV.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import workloads as W
from repro.core.engine import make_executor
from repro.core.vm import run_sequential

DIEM = dict(cfg_reads=W.CHAIN_CFG_READS_DIEM)      # 21 reads / 4 writes
APTOS = dict(cfg_reads=W.CHAIN_CFG_READS_APTOS)    # 8 reads / 5 writes


def _run_engine(spec, n_txns, window, seed=0, reps=3, backend="sorted",
                validation_window=0):
    cfg = W.p2p_engine_config(spec, n_txns, window=window, backend=backend,
                              validation_window=validation_window)
    run = make_executor(W.p2p_program(spec), cfg)
    params, storage = W.make_p2p_block(spec, n_txns, seed=seed)
    res = run(params, storage)                      # compile + warm
    res.snapshot.block_until_ready()
    assert bool(res.committed)
    times = []
    for r in range(reps):
        params, storage = W.make_p2p_block(spec, n_txns, seed=seed + r)
        t0 = time.perf_counter()
        res = run(params, storage)
        res.snapshot.block_until_ready()
        times.append(time.perf_counter() - t0)
    t = float(np.median(times))
    return dict(tps=n_txns / t, seconds=t, waves=int(res.waves),
                execs=int(res.execs), dep_aborts=int(res.dep_aborts),
                val_aborts=int(res.val_aborts))


def _run_sequential(spec, n_txns, seed=0):
    params, storage = W.make_p2p_block(spec, n_txns, seed=seed)
    t0 = time.perf_counter()
    run_sequential(W.p2p_program(spec), params, storage, n_txns)
    t = time.perf_counter() - t0
    return dict(tps=n_txns / t, seconds=t)


def _run_bohm(spec, n_txns, window, seed=0):
    """Bohm [21] with perfect write sets (real implementation,
    core/baselines.py): dependency-exact fork-join schedule, zero wasted
    executions.  Write-set extraction (the information the paper grants Bohm
    'artificially') is excluded from the timing, as in the paper."""
    import jax
    from repro.core import baselines as B
    cfg = W.p2p_engine_config(spec, n_txns, window=window)
    params, storage = W.make_p2p_block(spec, n_txns, seed=seed)
    pws = B.perfect_write_sets(W.p2p_program(spec), params, storage, cfg)
    run = jax.jit(lambda p, s: B.run_bohm(W.p2p_program(spec), p, s, cfg,
                                          pws))
    res = run(params, storage)
    res.snapshot.block_until_ready()
    t0 = time.perf_counter()
    res = run(params, storage)
    res.snapshot.block_until_ready()
    t = time.perf_counter() - t0
    return dict(tps=n_txns / t, seconds=t)


def _run_litm(spec, n_txns, seed=0):
    """LiTM [52]-style deterministic STM rounds (core/baselines.py)."""
    import jax
    from repro.core import baselines as B
    cfg = W.p2p_engine_config(spec, n_txns)
    params, storage = W.make_p2p_block(spec, n_txns, seed=seed)
    run = jax.jit(lambda p, s: B.run_litm(W.p2p_program(spec), p, s, cfg))
    res = run(params, storage)
    res.snapshot.block_until_ready()
    t0 = time.perf_counter()
    res = run(params, storage)
    res.snapshot.block_until_ready()
    t = time.perf_counter() - t0
    return dict(tps=n_txns / t, seconds=t, execs=int(res.execs))


def bench_threads(rows, profile_name, profile, n_txns=1000, accounts=1000):
    spec = W.P2PSpec(n_accounts=accounts, **profile)
    seq = _run_sequential(spec, n_txns)
    rows.append((f"fig3_{profile_name}_seq", seq["seconds"] * 1e6 / n_txns,
                 f"tps={seq['tps']:.0f}"))
    for vthreads in (1, 2, 4, 8, 16, 32):
        r = _run_engine(spec, n_txns, window=vthreads)
        rows.append((f"fig3_{profile_name}_bstm_t{vthreads}",
                     r["seconds"] * 1e6 / n_txns,
                     f"tps={r['tps']:.0f};speedup={r['tps']/seq['tps']:.2f};"
                     f"execs={r['execs']};waves={r['waves']}"))
    b = _run_bohm(spec, n_txns, window=32)
    rows.append((f"fig3_{profile_name}_bohm_t32", b["seconds"] * 1e6 / n_txns,
                 f"tps={b['tps']:.0f}"))
    l = _run_litm(spec, n_txns)
    rows.append((f"fig3_{profile_name}_litm", l["seconds"] * 1e6 / n_txns,
                 f"tps={l['tps']:.0f};execs={l['execs']}"))


def bench_contention(rows, profile_name, profile, n_txns=1000):
    for accounts in (2, 10, 100, 1000, 10000):
        spec = W.P2PSpec(n_accounts=accounts, **profile)
        seq = _run_sequential(spec, n_txns)
        r = _run_engine(spec, n_txns, window=32)
        rows.append((f"fig4_{profile_name}_acc{accounts}",
                     r["seconds"] * 1e6 / n_txns,
                     f"tps={r['tps']:.0f};seq_tps={seq['tps']:.0f};"
                     f"speedup={r['tps']/seq['tps']:.2f};"
                     f"execs_per_txn={r['execs']/n_txns:.2f};"
                     f"val_aborts={r['val_aborts']}"))
        # beyond-paper optimized variant (§Perf): windowed validation,
        # dense MV backend when the location universe is tiny (<=64 locs;
        # measured crossover — at L~200 the per-wave dense table rebuild
        # costs more than the sort it replaces)
        backend = "dense" if spec.n_locs <= 64 else "sorted"
        o = _run_engine(spec, n_txns, window=32, validation_window=128,
                        backend=backend)
        rows.append((f"fig4_{profile_name}_acc{accounts}_opt",
                     o["seconds"] * 1e6 / n_txns,
                     f"tps={o['tps']:.0f};speedup={o['tps']/seq['tps']:.2f};"
                     f"vs_base={o['tps']/r['tps']:.2f}x;backend={backend}"))


def bench_blocksize(rows, profile_name, profile, accounts=1000):
    for n_txns in (100, 1000, 5000, 10000):
        spec = W.P2PSpec(n_accounts=accounts, **profile)
        r = _run_engine(spec, n_txns, window=32, reps=2)
        rows.append((f"fig5_{profile_name}_n{n_txns}",
                     r["seconds"] * 1e6 / n_txns,
                     f"tps={r['tps']:.0f};waves={r['waves']}"))
        # optimized: window scales with block size + windowed validation
        w = max(32, min(256, n_txns // 64))
        o = _run_engine(spec, n_txns, window=w, validation_window=4 * w,
                        reps=2)
        rows.append((f"fig5_{profile_name}_n{n_txns}_opt",
                     o["seconds"] * 1e6 / n_txns,
                     f"tps={o['tps']:.0f};waves={o['waves']};window={w};"
                     f"vs_base={o['tps']/r['tps']:.2f}x"))


def bench_backends(rows, n_txns=512, accounts=200):
    for backend in ("sorted", "dense"):
        spec = W.P2PSpec(n_accounts=accounts)
        r = _run_engine(spec, n_txns, window=32, backend=backend)
        rows.append((f"backend_{backend}", r["seconds"] * 1e6 / n_txns,
                     f"tps={r['tps']:.0f}"))


def run_all(fast: bool = True):
    rows: list = []
    profiles = [("aptos", APTOS), ("diem", DIEM)]
    n = 512 if fast else 1000
    for name, prof in profiles:
        bench_threads(rows, name, prof, n_txns=n)
        bench_contention(rows, name, prof, n_txns=n)
    bench_blocksize(rows, "aptos", APTOS)
    bench_backends(rows)
    return rows

"""Multi-device engine benchmark: per-wave phase timings across mesh sizes.

Env-var contract: ``--xla_force_host_platform_device_count`` must reach XLA
BEFORE jax initializes its backend, so this module appends it to
``XLA_FLAGS`` at import line one (the ``launch/dryrun.py`` convention;
``engine_bench --devices N`` documents the same contract).  Everything here
runs on virtual CPU devices — the comparable quantities are the *shapes*:
with regions-per-device held fixed, the shard-local ``update`` phase does
identical per-device work no matter how many devices (and therefore how many
TOTAL regions) the mesh has, which is the scaling contract that matters on
real hardware.

Grid: devices {1, 2, 8} x zipf_s {0, 1.1} x n_locs {1e5, 1e7}, with
``n_shards = REGIONS_PER_DEVICE * devices`` so per-device region count stays
constant.  Each cell replays the engine's own shard_mapped phase functions
(:func:`repro.core.dist.engine.make_phase_fns`) wave by wave — execute /
index(update) / validate timed per wave, the final snapshot once — and
records end-to-end jitted tps for the dist engine AND the single-device
``sharded`` engine on the identical block (the exactness cross-check asserts
byte-identical snapshots while it is at it).

Execute-phase scaling cells: the execute phase partitions each wave's lanes
``ceil(window / D)`` per device, so each cell also records
``lanes_per_device`` and the per-wave ``routed_read_bytes_per_device`` —
the live routed payload (query out: loc+reader, 8 B; answer back: one
``ReadResolution``, 14 B; ``max_reads`` read sites per lane).  Bucket
CAPACITY in the two-hop exchange is provisioned worst-case (window-wide,
so routing can never overflow); the payload is what shrinks as devices
grow, and the ``exec_scaling_*`` headlines record exactly that.

Output: ``BENCH_dist.json`` at the repo root (uploaded as a CI artifact by
the ``test-dist`` job, which also gates a fresh record against the
committed baseline via ``benchmarks/check_regression.py``).

  PYTHONPATH=src python -m benchmarks.dist_bench --fast
"""
from __future__ import annotations

import os

_COUNT = int(os.environ.get("REPRO_DIST_BENCH_DEVICES", "8"))
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_COUNT}").strip()

import dataclasses  # noqa: E402

import jax          # noqa: E402  (must come after XLA_FLAGS is set)
import numpy as np  # noqa: E402

from benchmarks import registry as REG         # noqa: E402
from repro.core import workloads as W          # noqa: E402
from repro.core.dist.engine import make_phase_fns  # noqa: E402
from repro.core.engine import make_executor    # noqa: E402
from repro.launch.mesh import make_mesh        # noqa: E402

#: Fixed per-device region count: total regions scale with the mesh, local
#: update work does not — the claim BENCH_dist.json exists to record.
REGIONS_PER_DEVICE = 4

#: Routed execute-read payload per live lane read: query out (loc + reader,
#: two i32) + answer back (ReadResolution: found u8, writer/slot/inc i32,
#: is_estimate u8).
ROUTED_READ_BYTES = (2 * 4) + (4 * 3 + 2)


def exec_lane_stats(cfg, devices: int) -> dict:
    """Static execute-partition quantities for one cell (pure arithmetic,
    so the committed record is reproducible byte-for-byte)."""
    lanes = -(-cfg.window // devices)
    return {
        "lanes_per_device": lanes,
        "routed_read_bytes_per_device": lanes * cfg.max_reads
        * ROUTED_READ_BYTES,
    }


def _timed_call(fn, *args, inner=1):
    return REG.timed(fn, args, reps=1, inner=inner, warm=False, check=None)


def phase_timings(vm, params, storage, cfg, reps=1):
    """Per-wave phase wall-clock of the dist engine (hotpath_bench style)."""
    ph = make_phase_fns(vm, params, storage, cfg)
    state = ph["init"]()                       # warm/compile every phase
    state, delta = ph["execute"](state)
    state = ph["index"](state, delta)
    jax.block_until_ready(ph["validate"](state))
    jax.block_until_ready(ph["snapshot"](state))

    out = {k: [] for k in ("execute", "index", "validate")}
    waves = 0
    for _ in range(reps):
        state = ph["init"]()
        waves = 0
        while bool(state.frontier < cfg.n_txns) and waves < cfg.waves_cap():
            (state, delta), t = _timed_call(ph["execute"], state)
            out["execute"].append(t)
            state, t = _timed_call(ph["index"], state, delta, inner=3)
            out["index"].append(t)
            state, t = _timed_call(ph["validate"], state)
            out["validate"].append(t)
            waves += 1
        assert bool(state.frontier >= cfg.n_txns), "block did not commit"
    snap, snap_t = _timed_call(ph["snapshot"], state, inner=2)
    ms = {k: float(np.median(v) * 1e3) for k, v in out.items()}
    ms["snapshot"] = snap_t * 1e3
    return ms, waves, np.asarray(snap)


def _end_to_end(vm, params, storage, cfg, reps=2):
    run = make_executor(vm, cfg)
    res, t = REG.timed(run, (params, storage), reps=reps)
    return np.asarray(res.snapshot), cfg.n_txns / t


def phase_cost_table(vm, params, storage, dcfg, devices: int) -> dict:
    """Compiled-artifact accounting of the dist engine's phases, with the
    routed-exchange collective cross-check.

    Lowers the SAME shard_mapped phase callables the replay times and walks
    their post-SPMD HLO.  The execute phase's ``all-to-all`` totals must
    decompose into 7-array routed exchanges whose per-device bucket bytes,
    times ``max_reads``, equal the hand-computed
    ``routed_read_bytes_per_device`` this record has carried since PR 7 —
    :func:`repro.obs.cost.crosscheck_routed_read_bytes` raises otherwise,
    so a committed BENCH_dist.json certifies the compiled wire format."""
    from repro.obs import cost as C
    ph = make_phase_fns(vm, params, storage, dcfg)
    state0 = ph["init"]()
    state1, delta = ph["execute"](state0)
    costs = C.phase_costs({
        "execute": (ph["execute"], state0),
        "index": (ph["index"], state1, delta),
        "validate": (ph["validate"], ph["index"](state1, delta)),
        "snapshot": (ph["snapshot"], state1),
    })
    expected = exec_lane_stats(dcfg, devices)["routed_read_bytes_per_device"]
    costs["execute"]["routed_exchange"] = C.crosscheck_routed_read_bytes(
        costs["execute"], devices, dcfg.max_reads, expected)
    return costs


def run_grid(n_txns=512, reps=1):
    # honor a smaller forced host platform (REPRO_DIST_BENCH_DEVICES < 8)
    devices_axis = tuple(d for d in (1, 2, 8) if d <= len(jax.devices()))
    n_locs_axis = (10**5, 10**7)
    zipf_axis = (0.0, 1.1)
    record = {"n_txns": n_txns,
              "regions_per_device": REGIONS_PER_DEVICE,
              "host_devices": len(jax.devices()), "grid": {},
              "note": ("virtual CPU devices serialize on one host: per-wave "
                       "wall-clock grows with the device count's dispatch "
                       "overhead, while per-DEVICE update work is constant "
                       "— flat across n_locs and total region count at "
                       "fixed regions-per-device within each device count")}
    for d in devices_axis:
        mesh = make_mesh("regions", (d,))
        for n_locs in n_locs_axis:
            n_shards = REGIONS_PER_DEVICE * d
            for zipf_s in zipf_axis:
                name = f"D{d}_L{n_locs}_z{zipf_s}"
                vm, params, storage, cfg = W.make_mixed_block(
                    W.MixedSpec(), n_txns, seed=7, n_locs=n_locs,
                    zipf_s=zipf_s, backend="sharded", n_shards=n_shards)
                dcfg = dataclasses.replace(cfg, dist=True, mesh=mesh)
                ms, waves, snap = phase_timings(vm, params, storage, dcfg,
                                                reps=reps)
                dist_snap, dist_tps = _end_to_end(vm, params, storage, dcfg)
                ref_snap, ref_tps = _end_to_end(vm, params, storage, cfg)
                # the bench must be measuring the exact engine, every cell
                np.testing.assert_array_equal(dist_snap, ref_snap)
                np.testing.assert_array_equal(snap, ref_snap)
                record["grid"][name] = dict(
                    devices=d, n_shards=n_shards, waves=waves,
                    per_wave_ms=ms, tps_dist=dist_tps,
                    tps_single_device=ref_tps,
                    **exec_lane_stats(dcfg, d))
                print(f"{name}: update {ms['index']:.3f}ms/wave "
                      f"(S={n_shards}), exec {ms['execute']:.3f}ms "
                      f"({-(-dcfg.window // d)} lanes/dev), "
                      f"val {ms['validate']:.3f}ms, snap {ms['snapshot']:.1f}"
                      f"ms  e2e {dist_tps:.0f} tps (1-dev {ref_tps:.0f})")
    # headline: shard-local update cost vs device count at fixed rpd
    for n_locs in n_locs_axis:
        for zipf_s in zipf_axis:
            by_d = {d: record["grid"][f"D{d}_L{n_locs}_z{zipf_s}"]
                    ["per_wave_ms"]["index"] for d in devices_axis}
            key = f"update_ms_by_devices_L{n_locs}_z{zipf_s}"
            record[key] = by_d
            record[key + "_max_over_min"] = max(by_d.values()) / \
                max(min(by_d.values()), 1e-9)
    # headline: the execute partition scales down with the mesh — lane count
    # and live routed-read payload per device must strictly decrease (the
    # wall-clock column is informational: virtual CPU devices serialize, so
    # per-wave execute time reflects dispatch overhead, not the partition)
    for n_locs in n_locs_axis:
        for zipf_s in zipf_axis:
            cells = {d: record["grid"][f"D{d}_L{n_locs}_z{zipf_s}"]
                     for d in devices_axis}
            record[f"exec_scaling_L{n_locs}_z{zipf_s}"] = {
                d: dict(execute_ms=c["per_wave_ms"]["execute"],
                        lanes_per_device=c["lanes_per_device"],
                        routed_read_bytes_per_device=c[
                            "routed_read_bytes_per_device"])
                for d, c in cells.items()}
            bytes_by_d = [cells[d]["routed_read_bytes_per_device"]
                          for d in devices_axis]
            assert all(a > b for a, b in zip(bytes_by_d, bytes_by_d[1:])), \
                f"routed payload must shrink with the mesh: {bytes_by_d}"
    return record


# ---------------------------------------------------------------------------
# Registered suite
# ---------------------------------------------------------------------------

DIST = REG.register_suite(
    "dist",
    doc="multi-device engine over the regions mesh: per-wave phase timings "
        "and dist-vs-single-device tps across device counts, with "
        "HLO-walked collective accounting cross-checked against the "
        "hand-computed routed-read payload",
    needs_devices=8)


@REG.register_benchmark(DIST, "dist_grid", impls=("dist", "single_device"))
def _dist_grid(ctx):
    """devices x n_locs x zipf_s grid: phase replay, e2e tps for the dist
    and single-device engines on identical blocks, exec-partition scaling
    headlines."""
    reps = int(ctx.params.get("reps") or 0) or (1 if ctx.fast else 3)
    ctx.params["reps"] = reps
    ctx.record.update(run_grid(n_txns=ctx.size(512, 512), reps=reps))


@REG.register_benchmark(DIST, "exchange_cost")
def _dist_exchange_cost(ctx):
    """Per-phase compiled-artifact costs for the largest-mesh contended
    cell, including the all-to-all routed-exchange cross-check (raises on
    any drift between the compiled wire format and the committed
    structural record)."""
    n_txns = ctx.size(512, 512)
    d = max((x for x in (1, 2, 8) if x <= len(jax.devices())), default=1)
    if d <= 1:
        ctx.record["cost_skipped"] = "needs a multi-device mesh"
        return
    n_locs, zipf_s = 10**5, 1.1
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), n_txns, seed=7, n_locs=n_locs, zipf_s=zipf_s,
        backend="sharded", n_shards=REGIONS_PER_DEVICE * d)
    dcfg = dataclasses.replace(cfg, dist=True,
                               mesh=make_mesh("regions", (d,)))
    ctx.record["cost_cell"] = f"D{d}_L{n_locs}_z{zipf_s}"
    ctx.record["cost_devices"] = d
    ctx.record["cost"] = phase_cost_table(vm, params, storage, dcfg, d)


REG.register_metric(DIST, "tps_dist", scope="cell")
REG.register_metric(DIST, "tps_single_device", scope="cell")
# Static partition quantities: pure arithmetic of (window, devices,
# max_reads) — any drift between comparable runs is structural.
REG.register_metric(DIST, "lanes_per_device", scope="cell",
                    direction="exact")
REG.register_metric(DIST, "routed_read_bytes_per_device", scope="cell",
                    direction="exact")
# The HLO side of the cross-check: the compiled execute phase's per-device
# routed payload, derived from the all-to-all shapes alone.
REG.register_metric(
    DIST, "cost.execute.routed_exchange.routed_read_bytes_per_device_hlo",
    direction="exact")
REG.register_metric(DIST, "cost.execute.collective_counts.all-to-all",
                    direction="exact")


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false",
                    help="more replay reps per cell (tighter medians)")
    ap.add_argument("--n-txns", type=int, default=512)
    ap.add_argument("--reps", type=int, default=0,
                    help="0 = auto: 1 rep under --fast, 3 under --full")
    ap.add_argument("--out", default=None,
                    help="write the record here instead of the repo-root "
                    "BENCH_dist.json (CI writes a fresh record next to the "
                    "committed baseline and gates one against the other)")
    args = ap.parse_args()
    record, path = REG.run_suite("dist", fast=args.fast, out=args.out,
                                 n_txns=args.n_txns, reps=args.reps)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()

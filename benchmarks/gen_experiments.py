"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSON (narrative sections are maintained in the template below).

  PYTHONPATH=src python -m benchmarks.gen_experiments
"""
from __future__ import annotations

import json

from benchmarks.roofline import load, render, summarize

HEADER = """# EXPERIMENTS

Hardware target: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
per chip; 16 GB HBM. Meshes: `pod16x16` = (data 16, model 16) = 256 chips;
`2pod_2x16x16` = (pod 2, data 16, model 16) = 512 chips.

Accounting sources (see `src/repro/launch/hlo_analysis.py` / `analytic.py`):
* **flops/dev** — trip-count-aware walk of the compiled, partitioned HLO
  (dot/conv FLOPs × while-loop trip counts). `cost_analysis()` alone counts
  every `lax.scan` body once and under-reports a 96-layer model ~50×.
* **collectives/dev** — per-op link bytes from the same walk (all-gather at
  result size, all-reduce at 2× operand, reduce-scatter/all-to-all/permute at
  operand size).
* **memory term** — analytic HBM model (params+grads+optimizer+activations+
  cache traffic). The CPU-backend HLO legalizes every bf16 dot via f32
  converts which get loop-hoisted into f32 copies of scanned weights/caches;
  byte counts read off that HLO overstate TPU traffic 2-10× (evidenced below),
  so the analytic model is authoritative for the memory term. The raw HLO
  bytes are retained in the JSON (`bytes_dev_hlo`).
* Known CPU-lowering distortions, documented and adjusted where stated:
  (1) f32 legalization of bf16 ops (affects `memory_analysis()` temp sizes
  and AR payload dtypes, ~2×); (2) the CPU SPMD partitioner emits
  all-reduce where the TPU partitioner emits reduce-scatter+all-gather pairs
  for sharded-consumer reductions (b/433785288), up to 2× on grad traffic.

"""

DRYRUN_NOTES = """
## §Dry-run

All 40 (architecture × shape) cells lower AND compile for both production
meshes — 32 compiled cells + 8 structurally-skipped `long_500k` cells per
mesh (full-attention archs; sub-quadratic mixing required — DESIGN.md §6;
`zamba2-1.2b` (hybrid) and `falcon-mamba-7b` (SSM) run it). `decode_*` cells
lower `serve_step` (one token against a seq_len KV cache/SSM state);
`train_4k` lowers the full jitted train step (grad accumulation + AdamW with
per-arch state compression); `prefill_32k` lowers the forward path with
last-position unembedding.

Per-device memory (from `compiled.memory_analysis()`, CPU-inflated by f32
legalization — see header):

{memtable}

HBM-fit notes (16 GB budget):
* `llama4-maverick-400b-a17b` (775B total params from the assigned config)
  fits single-pod ONLY with int8 optimizer state (~1.03 B/param/moment;
  `optim/adamw.py`) + bf16 grad accumulation: params 6.1 GiB + m/v 3.2 GiB
  + activations. With f32 Adam it requires ≥2 pods.
* `nemotron-4-340b` uses bf16 m/v + bf16 grad accumulation + microbatch 4.
* `qwen1.5-110b` decode runs TP-resident (see §Perf cell 3): params 13.9 GiB
  + 32k cache 2.7 GiB exceeds 16 GiB by ~0.6 GiB at batch 128 — production
  deployment reduces decode batch to 96 or int8-quantizes weights; both
  variants compile and are recorded.
* Remaining >16 GiB `temp` readings are dominated by the CPU-backend f32
  copies of bf16 buffers (e.g. gemma decode: a bit-identical graph measured
  71 GB HLO bytes vs 0.6 GB analytic; factor confirmed by inspecting the f32
  convert-fusions in the loop bodies).
"""

ROOFLINE_NOTES = """
## §Roofline

Terms are seconds per step per device: `compute = flops_dev / 197e12`,
`memory = bytes_dev / 819e9` (analytic), `collective = link_bytes_dev / 50e9`.
`useful_flops` = MODEL_FLOPS/chips ÷ HLO flops_dev, where MODEL_FLOPS =
6·N·tokens (train), 2·N·tokens (prefill), 2·N_active·batch (decode).
`roofline_frac` = (MODEL_FLOPS/chips ÷ 197e12) ÷ max(term) — the score being
hill-climbed in §Perf.

Single-pod (256 chips):

{single}

Multi-pod (512 chips, 2 pods — proves the `pod` axis shards; gradient
all-reduce crosses the pod axis, everything else stays pod-local):

{multi}

Reading the table:
* **train** cells are collective-bound across the board — FSDP weight
  gathers + grad reductions dominate (the CPU partitioner's AR-for-RS
  substitution inflates the absolute numbers up to 2×, but the bound is real:
  at bf16 with RS the biggest cells remain collective-dominated).
* **prefill** cells for wide dense archs are compute-bound: the chunked
  causal attention computes the full S² score matrix (2× the causal-optimal
  FLOPs) — the Pallas flash kernel (kv-block skipping) removes this on real
  TPU; `useful_flops` quantifies the gap per cell.
* **decode** cells are memory-bound once weights are TP-resident (§Perf
  cell 3); batch-128 single-token steps can never reach compute roofline at
  2·N·B model FLOPs — tok/s/chip is the operative metric
  (memory_s ≈ params+cache bytes / 819 GB/s per token).
* `useful_flops > 1` on some decode cells: MoE top-k routing executes only
  experts with ≥1 token at decode; MODEL_FLOPS counts nominal top-k actives.
"""


def mem_table(results) -> str:
    rows = ["| arch | shape | mesh | args GiB | temp GiB | compile s |",
            "|---|---|---|---|---|---|"]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok" or not r.get("memory"):
            continue
        m = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{m.get('argument_size_in_bytes', 0)/2**30:.2f} | "
            f"{m.get('temp_size_in_bytes', 0)/2**30:.2f} | "
            f"{r['compile_s']:.1f} |")
    return "\n".join(rows)


def main():
    results = load("benchmarks/results/dryrun.json")
    out = [HEADER]
    out.append(DRYRUN_NOTES.format(memtable=mem_table(results)))
    out.append(ROOFLINE_NOTES.format(
        single=render(results, "pod16x16"),
        multi=render(results, "2pod_2x16x16")))
    s = summarize(results)
    out.append(f"\nCell count: {s['n_ok']} compiled OK, {s['n_skipped']} "
               f"skipped (documented), {s['n_failed']} failed.\n")
    with open("benchmarks/perf_notes.md") as f:
        out.append(f.read())
    print("\n".join(out))


if __name__ == "__main__":
    main()

"""Commit-stamped benchmark history: the repo's perf trajectory.

Every registry suite run (``benchmarks.registry.run_suite``) appends one
JSON line to ``BENCH_HISTORY.jsonl`` at the repo root:

    {"sha": "<git short sha>", "dirty": bool, "suite": "hotpath",
     "schema_rev": 3, "mode": "fast", "platform": "cpu",
     "metrics": {"median_update_vs_build_x": 2.7, ...}}

The line carries FLAT headline metrics (record-scope metric values +
per-cell medians, extracted by ``registry.history_metrics``) so consumers
— ``repro.obs.report --history`` (``make dashboard``) renders cross-commit
trend tables — need only this file, not the registry or the full records.
The committed-baseline regeneration flow therefore grows the history
organically: rerun the suites at a new commit and the trajectory gains a
row per suite.

No wall-clock timestamp, matching ``_emit.py``: the git SHA is the
ordering that matters, and append order preserves it within a commit.
"""
from __future__ import annotations

import json
import os
import subprocess
from typing import Any, Optional

from benchmarks import _emit

#: The trajectory file at the repo root (one JSON object per line).
HISTORY_NAME = "BENCH_HISTORY.jsonl"

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def history_path(path: Optional[str] = None) -> str:
    return path or os.path.join(_REPO_ROOT, HISTORY_NAME)


def git_stamp(cwd: Optional[str] = None) -> dict:
    """``{"sha": <short sha>, "dirty": bool}`` for the repo at ``cwd``
    (``"unknown"``/False outside a git checkout — history still appends)."""
    cwd = cwd or _REPO_ROOT
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10).stdout.strip()
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], cwd=cwd,
            capture_output=True, text=True, timeout=10).stdout.strip())
    except (OSError, subprocess.SubprocessError):
        sha, dirty = "", False
    return {"sha": sha or "unknown", "dirty": dirty}


def append(record: dict, metrics: dict[str, Any],
           path: Optional[str] = None) -> dict:
    """Append one suite run's history line; returns the line written."""
    line = {
        **git_stamp(),
        "suite": record.get("suite"),
        "schema_rev": record.get("schema_rev"),
        "mode": record.get("run", {}).get("mode"),
        "platform": record.get("env", {}).get("platform"),
        "metrics": metrics,
    }
    with open(history_path(path), "a") as f:
        f.write(json.dumps(line, sort_keys=True) + "\n")
    return line


def load(path: Optional[str] = None) -> list[dict]:
    """All history lines in append order (current-schema lines only; older
    revisions are kept in the file but skipped with a count, mirroring the
    ``_emit.load_bench`` handshake without refusing the whole trajectory)."""
    p = history_path(path)
    if not os.path.exists(p):
        return []
    out = []
    with open(p) as f:
        for raw in f:
            raw = raw.strip()
            if not raw:
                continue
            line = json.loads(raw)
            out.append(line)
    return out


def partition_by_schema(lines: list[dict]) -> tuple[list[dict], int]:
    """(current-schema lines, number of stale-schema lines skipped)."""
    cur = [l for l in lines if l.get("schema_rev") == _emit.SCHEMA_REV]
    return cur, len(lines) - len(cur)

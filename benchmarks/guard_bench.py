"""Guard/chaos overhead benchmark: what robustness costs on the hotpath.

One grid cell deliberately mirrored from ``benchmarks/hotpath_bench.py``
(same workload constructor, same ``n_txns=1024, seed=7`` block on the
sharded backend) so the records cross-gate: the ``guard_level=0 /
chaos=None`` throughput measured here is the SAME quantity as that cell's
``tps_incremental`` in the committed ``BENCH_hotpath.json``, and
``benchmarks/check_regression.py`` holds the two within the usual 10x
band — the robustness machinery must not tax the default path.

Measured variants (identical block, byte-identical committed snapshots —
asserted, not assumed):

* ``tps_guard{0,1,2}``  — in-jit invariant checking at each level
  (level 0 is the production default and the cross-gated number);
* ``tps_chaos``         — a full ``ChaosConfig`` schedule (all fault
  classes firing) at guard level 0: the price of an adversarial schedule,
  mostly extra waves;
* ``tps_degraded``      — a wave-starved block (``max_waves=1``) taking
  the sequential degradation fallback: the worst-case liveness floor.

Output: ``BENCH_guard.json`` at the repo root (CI artifact + gate input).

  PYTHONPATH=src python -m benchmarks.guard_bench --fast
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import registry as REG
from repro.core import workloads as W
from repro.core.engine import make_executor
from repro.guard import ChaosConfig

#: The hotpath grid cell this suite mirrors (same constructor arguments).
CELL = "L100000_s16_z1.1"
CELL_KW = dict(n_locs=10**5, zipf_s=1.1, backend="sharded", n_shards=16)


def _timed_run(vm, params, storage, cfg, reps):
    run = make_executor(vm, cfg)
    res, t = REG.timed(run, (params, storage), reps=reps)
    return res, cfg.n_txns / t


def run_variants(n_txns=1024, reps=3):
    vm, params, storage, cfg = W.make_mixed_block(
        W.MixedSpec(), n_txns, seed=7, **CELL_KW)
    record = {"n_txns": n_txns, "cell": CELL, "backend": "sharded"}

    variants = {
        "guard0": cfg,
        "guard1": dataclasses.replace(cfg, guard_level=1),
        "guard2": dataclasses.replace(cfg, guard_level=2),
        "chaos": dataclasses.replace(cfg, chaos=ChaosConfig(seed=7)),
        "degraded": dataclasses.replace(cfg, max_waves=1),
    }
    snap0 = None
    for name, vcfg in variants.items():
        res, tps = _timed_run(vm, params, storage, vcfg, reps)
        assert bool(res.committed), name
        assert bool(res.degraded) == (name == "degraded"), name
        if snap0 is None:
            snap0 = np.asarray(res.snapshot)
        else:
            # every variant commits the same preset-order state — a bench
            # that measured diverging executions would be comparing garbage
            np.testing.assert_array_equal(np.asarray(res.snapshot), snap0,
                                          err_msg=name)
        record[f"tps_{name}"] = tps
        record[f"waves_{name}"] = int(res.waves)
        print(f"{name}: {tps:.0f} tps  waves={int(res.waves)}")

    for lvl in (1, 2):
        record[f"guard{lvl}_overhead_x"] = (record["tps_guard0"]
                                            / record[f"tps_guard{lvl}"])
    record["chaos_overhead_x"] = record["tps_guard0"] / record["tps_chaos"]
    record["degraded_vs_normal_x"] = (record["tps_guard0"]
                                      / record["tps_degraded"])
    return record


# ---------------------------------------------------------------------------
# Registered suite
# ---------------------------------------------------------------------------

GUARD = REG.register_suite(
    "guard",
    doc="robustness overhead on the hotpath cell: guard levels 0/1/2, a "
        "full chaos schedule, and the sequential degradation fallback — "
        "identical block, byte-identical committed snapshots")


@REG.register_benchmark(GUARD, "variants",
                        impls=("guard0", "guard1", "guard2", "chaos",
                               "degraded"))
def _guard_variants(ctx):
    """All five variants on the mirrored hotpath cell (same constructor
    arguments, so tps_guard0 is cross-gated against BENCH_hotpath.json)."""
    reps = int(ctx.params.get("reps") or 0) or (2 if ctx.fast else 5)
    ctx.params["reps"] = reps
    ctx.record.update(run_variants(n_txns=ctx.size(1024, 1024), reps=reps))


for _name in ("tps_guard0", "tps_guard1", "tps_guard2", "tps_chaos",
              "tps_degraded"):
    REG.register_metric(GUARD, _name)


def _hotpath_cross_gate(baseline, fresh, check, notes):
    """Cross-record gate: the ``guard_level=0 / chaos=None`` throughput is
    measured on the same block as one committed ``BENCH_hotpath.json``
    grid cell (:data:`CELL`), so the robustness machinery landing a hidden
    tax on the default path shows up even before the guard baseline itself
    is regenerated."""
    from benchmarks._emit import bench_path, load_bench
    cell = fresh.get("cell")
    try:
        hotpath = load_bench(bench_path("hotpath"), expect_suite="hotpath")
    except (OSError, ValueError) as e:
        notes.append(f"hotpath cross-gate skipped: {e}")
        return
    hcell = hotpath.get("grid", {}).get(cell, {})
    if hotpath.get("n_txns") != fresh.get("n_txns"):
        notes.append(f"hotpath cross-gate skipped: n_txns "
                     f"{hotpath.get('n_txns')} != {fresh.get('n_txns')}")
    elif "tps_incremental" not in hcell:
        notes.append(f"hotpath cross-gate skipped: no cell {cell!r} in the "
                     f"committed BENCH_hotpath.json")
    else:
        check(f"hotpath:{cell}.tps_incremental vs tps_guard0",
              float(hcell["tps_incremental"]), float(fresh["tps_guard0"]))


GUARD.extra_gate = _hotpath_cross_gate


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    ap.add_argument("--n-txns", type=int, default=1024,
                    help="block size (1024 matches the cross-gated "
                    "hotpath cell; changing it disables the cross-gate)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the record here instead of the repo-root "
                    "BENCH_guard.json")
    args = ap.parse_args()
    record, path = REG.run_suite("guard", fast=args.fast, out=args.out,
                                 n_txns=args.n_txns, reps=args.reps or 0)
    print(f"wrote {path}  (guard2 overhead "
          f"{record['guard2_overhead_x']:.2f}x, chaos "
          f"{record['chaos_overhead_x']:.2f}x, degraded "
          f"{record['degraded_vs_normal_x']:.2f}x)")


if __name__ == "__main__":
    main()

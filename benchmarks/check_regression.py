"""CI perf-regression gate over the committed benchmark baselines.

Compares a freshly measured record (``hotpath_bench --out`` /
``dist_bench --out``) against the committed repo-root baseline of the
same suite and fails (exit 1) only on ORDER-OF-MAGNITUDE regressions —
CI machines are shared and noisy, so the default tolerance is 10x: the
gate exists to catch "the incremental path silently fell off a perf
cliff" (e.g. an accidental O(block) rebuild inside ``backend.update``,
the engine recompiling per wave, or the dist engine's throughput
collapsing under a routing change), not 20% jitter.

``hotpath`` records check, per grid cell present in BOTH records:

* ``tps_incremental``        — end-to-end engine throughput;
* ``update_vs_build_x``      — the incremental-maintenance advantage
                               (must not collapse toward the rebuild path);

plus the aggregate ``median_update_vs_build_x``.

``dist`` records check, per grid cell present in BOTH records:

* ``tps_dist``               — end-to-end dist-engine throughput;
* ``tps_single_device``      — the single-device reference on the same
                               block (so a shared slowdown reads as two
                               correlated notes, not a dist regression);

plus the structural execute-partition quantities (``lanes_per_device``,
``routed_read_bytes_per_device``): these are pure functions of the config,
so at equal block size any drift is a partition change, which fails the
gate outright.

``guard`` records (``guard_bench --out``) check every variant's
throughput (``tps_guard{0,1,2}`` / ``tps_chaos`` / ``tps_degraded``)
against the committed guard baseline, and additionally cross-gate
``tps_guard0`` against the committed hotpath baseline's mirrored grid
cell — the default path must not quietly pay for the robustness
machinery.

Cells present in only one record (grid drift) are reported but never fail
the gate.  Both records must carry the emitter's current ``schema_rev``
(``benchmarks/_emit.py``) — incomparable layouts refuse loudly instead
of comparing garbage; the suite is read from the fresh record and must
match the baseline's.

    PYTHONPATH=src python -m benchmarks.hotpath_bench --fast --out /tmp/fresh.json
    PYTHONPATH=src python -m benchmarks.check_regression /tmp/fresh.json
    PYTHONPATH=src python -m benchmarks.dist_bench --fast --out /tmp/fresh_dist.json
    PYTHONPATH=src python -m benchmarks.check_regression /tmp/fresh_dist.json
"""
from __future__ import annotations

import sys

from benchmarks._emit import bench_path, load_bench

#: Fail only when fresh is worse than baseline by this factor.
DEFAULT_TOLERANCE = 10.0

#: Per-cell higher-is-better metrics to gate on, by suite.
CELL_METRICS = ("tps_incremental", "update_vs_build_x")
DIST_CELL_METRICS = ("tps_dist", "tps_single_device")

#: Per-cell exact structural quantities of the dist execute partition.
DIST_STRUCTURAL = ("lanes_per_device", "routed_read_bytes_per_device")

#: Guard-suite higher-is-better metrics (benchmarks/guard_bench.py).
GUARD_METRICS = ("tps_guard0", "tps_guard1", "tps_guard2", "tps_chaos",
                 "tps_degraded")


def _checker(failures: list[str], notes: list[str], tolerance: float):
    def check(name: str, base_v: float, fresh_v: float) -> None:
        ratio = fresh_v / max(base_v, 1e-12)
        line = f"{name}: baseline {base_v:.3g} fresh {fresh_v:.3g} " \
               f"({ratio:.2f}x)"
        if fresh_v * tolerance < base_v:
            failures.append(line + f"  << {tolerance:.0f}x regression")
        else:
            notes.append(line)
    return check


def _grid_cells(baseline: dict, fresh: dict, notes: list[str]):
    """Yield (cell, base, fresh) for cells in BOTH records; note drift."""
    bgrid, fgrid = baseline.get("grid", {}), fresh.get("grid", {})
    for cell in sorted(set(bgrid) | set(fgrid)):
        if cell not in bgrid or cell not in fgrid:
            notes.append(f"{cell}: only in "
                         f"{'baseline' if cell in bgrid else 'fresh'} "
                         f"(grid drift, not gated)")
            continue
        yield cell, bgrid[cell], fgrid[cell]


def compare(baseline: dict, fresh: dict,
            tolerance: float = DEFAULT_TOLERANCE) -> tuple[list[str],
                                                           list[str]]:
    """Hotpath-suite gate. Returns (failures, notes); empty failures == OK."""
    failures: list[str] = []
    notes: list[str] = []
    check = _checker(failures, notes, tolerance)

    check("median_update_vs_build_x",
          float(baseline["median_update_vs_build_x"]),
          float(fresh["median_update_vs_build_x"]))
    for cell, b, f in _grid_cells(baseline, fresh, notes):
        if "error" in b or "error" in f:
            # int32-refusal cells carry no numbers; a refusal flipping
            # between records IS worth failing on — the config's
            # feasibility changed.  Only comparable at equal block size
            # (the refusal bound depends on n_txns).
            if ("error" in b) != ("error" in f):
                line = (f"{cell}: refusal state changed "
                        f"(baseline error={b.get('error')!r}, "
                        f"fresh error={f.get('error')!r})")
                if baseline.get("n_txns") == fresh.get("n_txns"):
                    failures.append(line)
                else:
                    notes.append(line + "  (different n_txns, not gated)")
            continue
        for metric in CELL_METRICS:
            check(f"{cell}.{metric}", float(b[metric]), float(f[metric]))
    return failures, notes


def compare_dist(baseline: dict, fresh: dict,
                 tolerance: float = DEFAULT_TOLERANCE) -> tuple[list[str],
                                                                list[str]]:
    """Dist-suite gate: throughput within the band, partition shape exact."""
    failures: list[str] = []
    notes: list[str] = []
    check = _checker(failures, notes, tolerance)
    comparable = baseline.get("n_txns") == fresh.get("n_txns")

    for cell, b, f in _grid_cells(baseline, fresh, notes):
        for metric in DIST_CELL_METRICS:
            check(f"{cell}.{metric}", float(b[metric]), float(f[metric]))
        for metric in DIST_STRUCTURAL:
            if metric not in b or metric not in f:
                continue
            if b[metric] != f[metric]:
                line = (f"{cell}.{metric}: baseline {b[metric]} "
                        f"fresh {f[metric]} — execute partition changed")
                if comparable:
                    failures.append(line)
                else:
                    notes.append(line + "  (different n_txns, not gated)")
            else:
                notes.append(f"{cell}.{metric}: {f[metric]} (exact)")
    return failures, notes


def compare_guard(baseline: dict, fresh: dict,
                  tolerance: float = DEFAULT_TOLERANCE) -> tuple[list[str],
                                                                 list[str]]:
    """Guard-suite gate: every variant's throughput within the band, PLUS
    the cross-gate against the committed hotpath baseline — the
    ``guard_level=0 / chaos=None`` number is measured on the same block as
    one ``BENCH_hotpath.json`` grid cell (``guard_bench.CELL``), so the
    robustness machinery landing a hidden tax on the default path shows
    up here even before the guard baseline itself is regenerated."""
    failures: list[str] = []
    notes: list[str] = []
    check = _checker(failures, notes, tolerance)

    for metric in GUARD_METRICS:
        if metric in baseline and metric in fresh:
            check(metric, float(baseline[metric]), float(fresh[metric]))

    cell = fresh.get("cell")
    try:
        hotpath = load_bench(bench_path("hotpath"), expect_suite="hotpath")
    except (OSError, ValueError) as e:
        notes.append(f"hotpath cross-gate skipped: {e}")
        return failures, notes
    hcell = hotpath.get("grid", {}).get(cell, {})
    if hotpath.get("n_txns") != fresh.get("n_txns"):
        notes.append(f"hotpath cross-gate skipped: n_txns "
                     f"{hotpath.get('n_txns')} != {fresh.get('n_txns')}")
    elif "tps_incremental" not in hcell:
        notes.append(f"hotpath cross-gate skipped: no cell {cell!r} in the "
                     f"committed BENCH_hotpath.json")
    else:
        check(f"hotpath:{cell}.tps_incremental vs tps_guard0",
              float(hcell["tps_incremental"]), float(fresh["tps_guard0"]))
    return failures, notes


_SUITES = {"hotpath": compare, "dist": compare_dist, "guard": compare_guard}


def main(argv: list[str] | None = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="freshly measured record "
                    "(hotpath_bench --out / dist_bench --out)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline (default: the repo-root "
                    "BENCH_<suite>.json matching the fresh record's suite)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="fail when fresh is worse by this factor "
                    "(default: %(default)s)")
    args = ap.parse_args(argv)
    fresh = load_bench(args.fresh)
    suite = fresh.get("suite")
    if suite not in _SUITES:
        sys.exit(f"{args.fresh}: suite {suite!r} has no gate "
                 f"(known: {sorted(_SUITES)})")
    baseline = load_bench(args.baseline or bench_path(suite),
                          expect_suite=suite)
    failures, notes = _SUITES[suite](baseline, fresh,
                                     tolerance=args.tolerance)
    for line in notes:
        print("  " + line)
    if failures:
        print(f"\nPERF REGRESSION ({len(failures)} metric(s) beyond "
              f"{args.tolerance:.0f}x):", file=sys.stderr)
        for line in failures:
            print("  " + line, file=sys.stderr)
        sys.exit(1)
    print(f"\nperf gate OK [{suite}]: {len(notes)} metrics within "
          f"{args.tolerance:.0f}x of baseline")


if __name__ == "__main__":
    main()

"""Registry-driven CI perf-regression gate over every benchmark suite.

One generic loop replaces the per-suite compare functions: each suite's
gate contract lives in its :mod:`benchmarks.registry` metric declarations
(direction ``higher`` / ``lower`` / ``exact``, tolerance band, scope
``record`` / ``cell``), so adding a metric to a suite automatically gates
it here.  The gate fails (exit 1) only on ORDER-OF-MAGNITUDE regressions
by default — CI machines are shared and noisy, so 10x: it exists to catch
"the incremental path silently fell off a perf cliff" (an accidental
O(block) rebuild inside ``backend.update``, the engine recompiling per
wave, dist throughput collapsing under a routing change), not 20% jitter.

Beyond the band checks:

* ``direction='exact'`` metrics (partition shapes, schedule waves/execs,
  recompile counts, the HLO-derived routed-read payload) fail on ANY
  drift between comparable runs — they are structural, not noisy;
* grid cells present in only one record are reported but never fail
  (grid drift); an int32-refusal cell FLIPPING between records fails when
  the runs are comparable — the config's feasibility changed;
* aggregate metrics (grid-wide medians) are refused outright between
  runs with different run metadata (``--fast`` vs ``--full``, different
  grid params): :class:`benchmarks._emit.IncomparableRunsError` instead
  of silently comparing medians over different cell sets;
* a suite's ``extra_gate`` hook runs last (the guard suite cross-gates
  ``tps_guard0`` against the committed hotpath baseline's mirrored cell).

Two entry points:

    # gate one fresh record against its committed baseline
    PYTHONPATH=src python -m benchmarks.hotpath_bench --fast --out /tmp/fresh.json
    PYTHONPATH=src python -m benchmarks.check_regression /tmp/fresh.json

    # measure + gate EVERY registered suite (CI's make check-regression-all)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.check_regression --run-all
"""
from __future__ import annotations

import sys

from benchmarks import registry as REG
from benchmarks._emit import IncomparableRunsError, bench_path, load_bench

#: Fail only when fresh is worse than baseline by this factor.
DEFAULT_TOLERANCE = 10.0


def _runs_comparable(baseline: dict, fresh: dict) -> bool:
    """Measured over the same cell set: identical run metadata (mode +
    grid params, stamped by ``_emit.write_bench``)."""
    return baseline.get("run") == fresh.get("run")


def _grid_cells(baseline: dict, fresh: dict, notes: list[str]):
    """Yield (cell, base, fresh) for cells in BOTH records; note drift."""
    bgrid, fgrid = baseline.get("grid", {}), fresh.get("grid", {})
    for cell in sorted(set(bgrid) | set(fgrid)):
        if cell not in bgrid or cell not in fgrid:
            notes.append(f"{cell}: only in "
                         f"{'baseline' if cell in bgrid else 'fresh'} "
                         f"(grid drift, not gated)")
            continue
        yield cell, bgrid[cell], fgrid[cell]


def compare_records(suite, baseline: dict, fresh: dict,
                    tolerance: float = DEFAULT_TOLERANCE
                    ) -> tuple[list[str], list[str]]:
    """Gate one fresh record against its baseline by the suite's declared
    metrics.  Returns (failures, notes); empty failures == OK."""
    failures: list[str] = []
    notes: list[str] = []
    comparable = _runs_comparable(baseline, fresh)
    aggregates = [m for m in suite.metrics.values() if m.aggregate]
    if aggregates and not comparable:
        raise IncomparableRunsError(
            f"suite {suite.name!r}: aggregate metrics "
            f"{sorted(m.name for m in aggregates)} cannot be compared "
            f"between runs with different metadata — baseline run "
            f"{baseline.get('run')}, fresh run {fresh.get('run')}; "
            f"regenerate one side with the other's mode/params")

    def check(name, base_v, fresh_v, metric=None):
        direction = metric.direction if metric is not None else "higher"
        tol = tolerance if metric is None or metric.tolerance is None \
            else metric.tolerance
        if direction == "exact":
            if base_v != fresh_v:
                line = (f"{name}: baseline {base_v!r} fresh {fresh_v!r} "
                        f"— structural drift")
                if comparable:
                    failures.append(line)
                else:
                    notes.append(line + "  (runs not comparable, not gated)")
            else:
                notes.append(f"{name}: {fresh_v!r} (exact)")
            return
        base_v, fresh_v = float(base_v), float(fresh_v)
        ratio = fresh_v / max(base_v, 1e-12)
        line = f"{name}: baseline {base_v:.3g} fresh {fresh_v:.3g} " \
               f"({ratio:.2f}x)"
        worse = (fresh_v * tol < base_v) if direction == "higher" \
            else (fresh_v > base_v * tol)
        if worse:
            failures.append(line + f"  << {tol:.0f}x regression")
        else:
            notes.append(line)

    for m in suite.record_metrics():
        bv, fv = REG._dig(baseline, m.name), REG._dig(fresh, m.name)
        if bv is None and fv is None:
            continue
        if fv is None:
            # the record contract shrank: a metric the baseline carries
            # vanished from fresh measurement — that IS a regression when
            # the runs are comparable (a silently dropped gate otherwise)
            (failures if comparable else notes).append(
                f"{m.name}: present in baseline, missing in fresh record")
            continue
        if bv is None:
            notes.append(f"{m.name}: new metric (no baseline value yet, "
                         f"gates after the baseline is regenerated)")
            continue
        check(m.name, bv, fv, m)

    cell_metrics = suite.cell_metrics()
    for cell, b, f in _grid_cells(baseline, fresh, notes):
        if "error" in b or "error" in f:
            # int32-refusal cells carry no numbers; a refusal flipping
            # between records IS worth failing on — the config's
            # feasibility changed.  Only gated between comparable runs
            # (the refusal bound depends on the grid params).
            if ("error" in b) != ("error" in f):
                line = (f"{cell}: refusal state changed "
                        f"(baseline error={b.get('error')!r}, "
                        f"fresh error={f.get('error')!r})")
                if comparable:
                    failures.append(line)
                else:
                    notes.append(line + "  (runs not comparable, not gated)")
            continue
        for m in cell_metrics:
            bv, fv = REG._dig(b, m.name), REG._dig(f, m.name)
            if bv is None or fv is None:
                notes.append(f"{cell}.{m.name}: missing in "
                             f"{'baseline' if bv is None else 'fresh'} "
                             f"(not gated)")
                continue
            check(f"{cell}.{m.name}", bv, fv, m)

    if suite.extra_gate is not None:
        suite.extra_gate(baseline, fresh, check, notes)
    return failures, notes


def _report(suite_name: str, failures: list[str], notes: list[str],
            tolerance: float) -> bool:
    for line in notes:
        print("  " + line)
    if failures:
        print(f"\nPERF REGRESSION [{suite_name}] ({len(failures)} "
              f"metric(s)):", file=sys.stderr)
        for line in failures:
            print("  " + line, file=sys.stderr)
        return False
    print(f"perf gate OK [{suite_name}]: {len(notes)} metrics within "
          f"{tolerance:.0f}x of baseline")
    return True


def run_all_gate(suites: list[str] | None = None,
                 tolerance: float = DEFAULT_TOLERANCE, fast: bool = True,
                 fresh_dir: str | None = None) -> int:
    """Measure a fresh record for every registered suite (devices
    permitting) and gate each against its committed baseline.  Returns the
    number of failing suites."""
    import os
    import tempfile

    import jax

    names = suites or sorted(REG.all_suites())
    fresh_dir = fresh_dir or tempfile.mkdtemp(prefix="bench_fresh_")
    os.makedirs(fresh_dir, exist_ok=True)
    failed = 0
    for name in names:
        suite = REG.get_suite(name)
        if suite.needs_devices > len(jax.devices()):
            print(f"[{name}] SKIPPED: needs {suite.needs_devices} devices, "
                  f"{len(jax.devices())} visible (set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count="
                  f"{suite.needs_devices})")
            continue
        print(f"[{name}] measuring fresh record ...")
        _, path = REG.run_suite(name, fast=fast,
                                out=os.path.join(fresh_dir,
                                                 f"BENCH_{name}.json"))
        fresh = load_bench(path, expect_suite=name)
        baseline = load_bench(bench_path(name), expect_suite=name)
        failures, notes = compare_records(suite, baseline, fresh,
                                          tolerance=tolerance)
        if not _report(name, failures, notes, tolerance):
            failed += 1
    return failed


def main(argv: list[str] | None = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", nargs="?", default=None,
                    help="freshly measured record (any suite's --out)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline (default: the repo-root "
                    "BENCH_<suite>.json matching the fresh record's suite)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="fail when fresh is worse by this factor "
                    "(default: %(default)s; per-metric declared tolerances "
                    "win)")
    ap.add_argument("--run-all", action="store_true",
                    help="measure + gate every registered suite "
                    "(make check-regression-all)")
    ap.add_argument("--suites", nargs="*", default=None,
                    help="with --run-all: restrict to these suites")
    ap.add_argument("--full", dest="fast", action="store_false",
                    default=True, help="with --run-all: measure in --full "
                    "mode (baselines are committed in --fast mode)")
    ap.add_argument("--fresh-dir", default=None,
                    help="with --run-all: write fresh records here "
                    "(default: a temp dir)")
    args = ap.parse_args(argv)

    REG.load_suites()
    if args.run_all:
        failed = run_all_gate(suites=args.suites, tolerance=args.tolerance,
                              fast=args.fast, fresh_dir=args.fresh_dir)
        if failed:
            sys.exit(1)
        return
    if not args.fresh:
        ap.error("a fresh record path is required (or pass --run-all)")
    fresh = load_bench(args.fresh)
    suite_name = fresh.get("suite")
    try:
        suite = REG.get_suite(suite_name)
    except REG.BenchRegistryError as e:
        sys.exit(f"{args.fresh}: {e}")
    baseline = load_bench(args.baseline or bench_path(suite_name),
                          expect_suite=suite_name)
    failures, notes = compare_records(suite, baseline, fresh,
                                      tolerance=args.tolerance)
    if not _report(suite_name, failures, notes, args.tolerance):
        sys.exit(1)


if __name__ == "__main__":
    main()

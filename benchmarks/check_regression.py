"""CI perf-regression gate over the committed hotpath baseline.

Compares a freshly measured ``BENCH_hotpath.json`` (written by
``hotpath_bench --out``) against the committed repo-root baseline and
fails (exit 1) only on ORDER-OF-MAGNITUDE regressions — CI machines are
shared and noisy, so the default tolerance is 10x: the gate exists to
catch "the incremental path silently fell off a perf cliff" (e.g. an
accidental O(block) rebuild inside ``backend.update``, or the engine
recompiling per wave), not 20% jitter.

Checked per grid cell present in BOTH records:

* ``tps_incremental``        — end-to-end engine throughput;
* ``update_vs_build_x``      — the incremental-maintenance advantage
                               (must not collapse toward the rebuild path);

plus the aggregate ``median_update_vs_build_x``.  Cells present in only
one record (grid drift) are reported but never fail the gate.  Both
records must carry the emitter's current ``schema_rev``
(``benchmarks/_emit.py``) — incomparable layouts refuse loudly instead
of comparing garbage.

    PYTHONPATH=src python -m benchmarks.hotpath_bench --fast --out /tmp/fresh.json
    PYTHONPATH=src python -m benchmarks.check_regression /tmp/fresh.json
"""
from __future__ import annotations

import sys

from benchmarks._emit import bench_path, load_bench

#: Fail only when fresh is worse than baseline by this factor.
DEFAULT_TOLERANCE = 10.0

#: Per-cell higher-is-better metrics to gate on.
CELL_METRICS = ("tps_incremental", "update_vs_build_x")


def compare(baseline: dict, fresh: dict,
            tolerance: float = DEFAULT_TOLERANCE) -> tuple[list[str],
                                                           list[str]]:
    """Returns (failures, notes); empty failures == gate passes."""
    failures: list[str] = []
    notes: list[str] = []

    def check(name: str, base_v: float, fresh_v: float) -> None:
        ratio = fresh_v / max(base_v, 1e-12)
        line = f"{name}: baseline {base_v:.3g} fresh {fresh_v:.3g} " \
               f"({ratio:.2f}x)"
        if fresh_v * tolerance < base_v:
            failures.append(line + f"  << {tolerance:.0f}x regression")
        else:
            notes.append(line)

    check("median_update_vs_build_x",
          float(baseline["median_update_vs_build_x"]),
          float(fresh["median_update_vs_build_x"]))
    bgrid, fgrid = baseline.get("grid", {}), fresh.get("grid", {})
    for cell in sorted(set(bgrid) | set(fgrid)):
        if cell not in bgrid or cell not in fgrid:
            notes.append(f"{cell}: only in "
                         f"{'baseline' if cell in bgrid else 'fresh'} "
                         f"(grid drift, not gated)")
            continue
        b, f = bgrid[cell], fgrid[cell]
        if "error" in b or "error" in f:
            # int32-refusal cells carry no numbers; a refusal flipping
            # between records IS worth failing on — the config's
            # feasibility changed.  Only comparable at equal block size
            # (the refusal bound depends on n_txns).
            if ("error" in b) != ("error" in f):
                line = (f"{cell}: refusal state changed "
                        f"(baseline error={b.get('error')!r}, "
                        f"fresh error={f.get('error')!r})")
                if baseline.get("n_txns") == fresh.get("n_txns"):
                    failures.append(line)
                else:
                    notes.append(line + "  (different n_txns, not gated)")
            continue
        for metric in CELL_METRICS:
            check(f"{cell}.{metric}", float(b[metric]), float(f[metric]))
    return failures, notes


def main(argv: list[str] | None = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="freshly measured BENCH_hotpath.json "
                    "(hotpath_bench --out)")
    ap.add_argument("--baseline", default=bench_path("hotpath"),
                    help="committed baseline (default: repo-root "
                    "BENCH_hotpath.json)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="fail when fresh is worse by this factor "
                    "(default: %(default)s)")
    args = ap.parse_args(argv)
    baseline = load_bench(args.baseline, expect_suite="hotpath")
    fresh = load_bench(args.fresh, expect_suite="hotpath")
    failures, notes = compare(baseline, fresh, tolerance=args.tolerance)
    for line in notes:
        print("  " + line)
    if failures:
        print(f"\nPERF REGRESSION ({len(failures)} metric(s) beyond "
              f"{args.tolerance:.0f}x):", file=sys.stderr)
        for line in failures:
            print("  " + line, file=sys.stderr)
        sys.exit(1)
    print(f"\nperf gate OK: {len(notes)} metrics within "
          f"{args.tolerance:.0f}x of baseline")


if __name__ == "__main__":
    main()

"""Unified benchmark-suite registry: one harness for every A/B in the repo.

The paper's evaluation is one disciplined grid — competing engines x
contention x thread count, every claim a measured cell (§6).  This module
makes the repo's benchmarks the same shape: a tritonbench-style registry
where every suite, benchmark, and metric is *declared*, and one shared
timing harness (warmup, reps, ``block_until_ready``, the committed-snapshot
assertion) produces every number.

Three declarations:

* :func:`register_suite` — a named suite owning one ``BENCH_<name>.json``
  record (``bytecode`` / ``baselines`` / ``shards`` / ``hotpath`` / ``dist``
  / ``guard``).  A suite is a collection of benchmarks plus the metric
  contract its record obeys.
* :func:`register_benchmark` — one measurement inside a suite, optionally
  naming its competing implementations (``impls=("switch", "gather")`` for
  the ALU A/B, ``("update", "rebuild")`` for MV maintenance, ...).  The
  decorated function receives a :class:`RunContext` and writes into
  ``ctx.record`` / ``ctx.rows``.
* :func:`register_metric` — a field of the suite record with a declared
  gate contract: direction (``higher`` / ``lower`` / ``exact``), tolerance
  band, scope (``record`` top-level vs per-``cell`` under ``record["grid"]``,
  dotted paths allowed), and whether it is an *aggregate* over the grid
  (aggregates are only comparable between runs with identical run metadata
  — ``benchmarks.check_regression`` refuses fast-vs-full with
  :class:`~benchmarks._emit.IncomparableRunsError`).

:func:`run_suite` executes a suite's benchmarks under one
:class:`RunContext`, emits the record through the schema-versioned
``benchmarks/_emit.py``, and appends a git-SHA-stamped line to
``BENCH_HISTORY.jsonl`` (``benchmarks/history.py``) so every run extends
the repo's perf trajectory.  ``benchmarks/check_regression.py`` walks the
same metric declarations to gate every suite — there is exactly one place
a metric's meaning is defined.

    PYTHONPATH=src python -m benchmarks.registry list
    PYTHONPATH=src python -m benchmarks.registry run hotpath --fast
    PYTHONPATH=src python -m benchmarks.registry run --all --fast
"""
from __future__ import annotations

import dataclasses
import importlib
import time
from typing import Any, Callable, Optional

import numpy as np

from benchmarks import history
from benchmarks._emit import load_bench, write_bench


class BenchRegistryError(ValueError):
    """Bad registration: duplicate names, unknown suites, bad metric specs."""


#: Modules that register the repo's suites on import (one harness for every
#: A/B: gather-vs-switch ALU, update-vs-rebuild, dist-vs-single, guard
#: on/off, ... — the ROADMAP's tritonbench-style consolidation).
SUITE_MODULES = (
    "benchmarks.engine_bench",    # bytecode, baselines, shards
    "benchmarks.hotpath_bench",   # hotpath
    "benchmarks.dist_bench",      # dist
    "benchmarks.guard_bench",     # guard
)

_DIRECTIONS = ("higher", "lower", "exact")
_SCOPES = ("record", "cell")


@dataclasses.dataclass(frozen=True)
class Metric:
    """One declared field of a suite record, with its gate contract.

    ``name`` is a key into the record (scope ``record``) or into each grid
    cell (scope ``cell``); dotted names traverse nested dicts (the
    baselines grid keeps ``{engine: {tps: ...}}`` cells, so its metrics are
    ``"blockstm.tps"`` etc.).  ``direction='exact'`` metrics are structural
    quantities (partition shapes, recompile counts): any drift between
    comparable runs fails the gate outright instead of being banded.
    """

    name: str
    direction: str = "higher"
    tolerance: Optional[float] = None     # None -> the gate's default band
    scope: str = "record"
    aggregate: bool = False   # summarises the whole grid: only comparable
    # between runs with identical run metadata (mode + params)

    def __post_init__(self):
        if self.direction not in _DIRECTIONS:
            raise BenchRegistryError(
                f"metric {self.name!r}: direction {self.direction!r} not in "
                f"{_DIRECTIONS}")
        if self.scope not in _SCOPES:
            raise BenchRegistryError(
                f"metric {self.name!r}: scope {self.scope!r} not in "
                f"{_SCOPES}")


@dataclasses.dataclass(frozen=True)
class Benchmark:
    """One registered measurement: ``fn(ctx)`` writing into the record."""

    name: str
    fn: Callable[["RunContext"], Any]
    impls: tuple[str, ...] = ()   # competing implementations (A/B labels)
    doc: str = ""


class Suite:
    """A named suite: benchmarks + the metric contract of its record."""

    def __init__(self, name: str, doc: str = "", needs_devices: int = 1):
        self.name = name
        self.doc = doc
        self.needs_devices = needs_devices   # virtual-mesh floor (dist: 8)
        self.benchmarks: dict[str, Benchmark] = {}
        self.metrics: dict[str, Metric] = {}
        #: Optional extra gate hook: ``fn(baseline, fresh, check, notes)``
        #: for suite-specific cross-record checks (the guard suite's
        #: tps_guard0-vs-hotpath cross-gate).
        self.extra_gate: Optional[Callable] = None

    def cell_metrics(self) -> list[Metric]:
        return [m for m in self.metrics.values() if m.scope == "cell"]

    def record_metrics(self) -> list[Metric]:
        return [m for m in self.metrics.values() if m.scope == "record"]

    def __repr__(self):
        return (f"Suite({self.name!r}, benchmarks="
                f"{sorted(self.benchmarks)}, metrics={sorted(self.metrics)})")


_SUITES: dict[str, Suite] = {}


def register_suite(name: str, doc: str = "",
                   needs_devices: int = 1) -> Suite:
    """Declare a suite.  Duplicate names are a registration error."""
    if name in _SUITES:
        raise BenchRegistryError(f"suite {name!r} already registered")
    suite = Suite(name, doc=doc, needs_devices=needs_devices)
    _SUITES[name] = suite
    return suite


def get_suite(name: str) -> Suite:
    if name not in _SUITES:
        raise BenchRegistryError(
            f"unknown suite {name!r} (registered: {sorted(_SUITES)})")
    return _SUITES[name]


def all_suites(load: bool = True) -> dict[str, Suite]:
    """The full registry (importing :data:`SUITE_MODULES` when ``load``)."""
    if load:
        load_suites()
    return dict(_SUITES)


def load_suites() -> None:
    """Import every suite-defining module (idempotent: modules register at
    import time and Python caches imports)."""
    for mod in SUITE_MODULES:
        importlib.import_module(mod)


def _resolve(suite: "str | Suite") -> Suite:
    return suite if isinstance(suite, Suite) else get_suite(suite)


def register_benchmark(suite: "str | Suite", name: Optional[str] = None,
                       impls: tuple[str, ...] = ()):
    """Decorator registering ``fn(ctx)`` as a benchmark of ``suite``."""
    s = _resolve(suite)

    def deco(fn):
        bname = name or fn.__name__
        if bname in s.benchmarks:
            raise BenchRegistryError(
                f"suite {s.name!r}: benchmark {bname!r} already registered")
        s.benchmarks[bname] = Benchmark(bname, fn, tuple(impls),
                                        doc=(fn.__doc__ or "").strip())
        return fn

    return deco


def register_metric(suite: "str | Suite", name: str, **kw) -> Metric:
    """Declare one gated metric of ``suite``'s record."""
    s = _resolve(suite)
    if name in s.metrics:
        raise BenchRegistryError(
            f"suite {s.name!r}: metric {name!r} already registered")
    m = Metric(name=name, **kw)
    s.metrics[name] = m
    return m


# ---------------------------------------------------------------------------
# Shared timing harness
# ---------------------------------------------------------------------------

def finish(res):
    """Block on the result and enforce the committed-snapshot contract.

    Every timed engine run must COMMIT — a bench that timed wave-capped,
    uncommitted executions would be reporting throughput for work that
    produced no state (the ``engine_bench._run_engine`` assertion, now the
    one harness-wide rule)."""
    res.snapshot.block_until_ready()
    assert bool(res.committed), "timed run did not commit its block"
    return res


def timed(fn, args, reps: int = 2, inner: int = 1, warm: bool = True,
          check: Optional[Callable] = finish):
    """Median wall-clock of ``reps`` calls of ``fn(*args)`` (same args).

    Compiles/warms once outside the timed region; ``inner > 1`` takes the
    best of ``inner`` back-to-back calls per rep (amortizing host dispatch
    jitter for sub-millisecond jitted phases — the hotpath/dist per-phase
    convention); ``check`` post-processes every result (default: the
    committed-snapshot assertion; pass ``jax.block_until_ready`` for
    results that are bare arrays/pytrees)."""
    import jax

    done = check if check is not None else jax.block_until_ready
    if warm:
        done(fn(*args))
    times = []
    out = None
    for _ in range(max(reps, 1)):
        best = float("inf")
        for _ in range(max(inner, 1)):
            t0 = time.perf_counter()
            out = fn(*args)
            done(out)
            best = min(best, time.perf_counter() - t0)
        times.append(best)
    return out, float(np.median(times))


def timed_blocks(run, make_args: Callable[[int], tuple], reps: int = 3,
                 check: Callable = finish):
    """Median wall-clock over ``reps`` FRESH blocks (``make_args(r)`` builds
    rep ``r``'s arguments; rep 0 compiles+warms untimed).  Each timed rep
    must pass ``check`` — the harness, not the caller, owns the
    committed-snapshot rule."""
    res = check(run(*make_args(0)))
    times = []
    for r in range(max(reps, 1)):
        args = make_args(r + 1)
        t0 = time.perf_counter()
        res = run(*args)
        check(res)
        times.append(time.perf_counter() - t0)
    return res, float(np.median(times))


# ---------------------------------------------------------------------------
# Running a suite
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunContext:
    """What a benchmark function receives: mode, grid params, and the
    record/rows it writes into."""

    fast: bool = True
    params: dict = dataclasses.field(default_factory=dict)
    record: dict = dataclasses.field(default_factory=dict)
    rows: list = dataclasses.field(default_factory=list)

    @property
    def mode(self) -> str:
        return "fast" if self.fast else "full"

    def size(self, fast_default: int, full_default: int,
             key: str = "n_txns") -> int:
        """The block size for this run: an explicit CLI/grid param wins,
        otherwise the suite's per-mode default.  Whatever is used is
        stamped into ``params`` so the record's run metadata names the
        actual grid (the fast-vs-full aggregate-comparison guard)."""
        n = self.params.get(key)
        if n is None:
            n = fast_default if self.fast else full_default
        self.params[key] = int(n)
        return int(n)


def history_metrics(suite: Suite, record: dict) -> dict:
    """Flat headline metrics for one history line: every record-scope
    metric present, plus the median over grid cells of every cell-scope
    metric (so the trajectory table has one number per metric per run)."""
    out: dict[str, Any] = {}
    for m in suite.record_metrics():
        v = _dig(record, m.name)
        if v is not None:
            out[m.name] = v
    cells = [c for c in record.get("grid", {}).values()
             if isinstance(c, dict) and "error" not in c]
    for m in suite.cell_metrics():
        vals = [_dig(c, m.name) for c in cells]
        vals = [v for v in vals if isinstance(v, (int, float))]
        if vals:
            key = f"median_{m.name.replace('.', '_')}"
            out[key] = (float(np.median(vals)) if m.direction != "exact"
                        else vals[0] if len(set(vals)) == 1 else None)
            if out[key] is None:
                del out[key]
    return out


def _dig(d: dict, dotted: str):
    """Nested lookup by dotted path; None when any hop is missing."""
    cur: Any = d
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def run_suite(name: str, fast: bool = True, out: Optional[str] = None,
              append_history: bool = True,
              history_path: Optional[str] = None,
              benchmarks: Optional[list[str]] = None,
              rows: Optional[list] = None,
              **params) -> tuple[dict, str]:
    """Run one suite's registered benchmarks under the shared harness.

    Returns ``(record, path)``.  The record is emitted through
    ``_emit.write_bench`` with run metadata (mode + grid params) stamped,
    and a history line is appended unless ``append_history=False``.
    ``rows``, when given, collects the benchmarks' CSV rows (the
    engine_bench CLI's figure-table output)."""
    suite = get_suite(name)
    ctx = RunContext(fast=fast, params=dict(params))
    if rows is not None:
        ctx.rows = rows
    for bname, bench in suite.benchmarks.items():
        if benchmarks is not None and bname not in benchmarks:
            continue
        bench.fn(ctx)
    path = write_bench(suite.name, ctx.record, out=out, mode=ctx.mode,
                       params=ctx.params)
    record = load_bench(path)        # the stamped record, as consumers see it
    if append_history:
        history.append(record, history_metrics(suite, record),
                       path=history_path)
    return record, path


def main(argv: Optional[list[str]] = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="print every registered suite / benchmark "
                   "/ metric")
    rp = sub.add_parser("run", help="run suites through the shared harness")
    rp.add_argument("suites", nargs="*", help="suite names (see `list`)")
    rp.add_argument("--all", action="store_true",
                    help="run every registered suite (devices permitting)")
    rp.add_argument("--fast", action="store_true", default=True)
    rp.add_argument("--full", dest="fast", action="store_false")
    rp.add_argument("--out", default=None,
                    help="write records under this dir instead of the "
                    "repo-root BENCH_<suite>.json baselines")
    rp.add_argument("--no-history", dest="history", action="store_false",
                    default=True, help="do not append BENCH_HISTORY.jsonl "
                    "lines")
    args = ap.parse_args(argv)

    load_suites()
    if args.cmd == "list":
        for name, suite in sorted(_SUITES.items()):
            print(f"{name}: {suite.doc}")
            for b in suite.benchmarks.values():
                ab = f"  [{' vs '.join(b.impls)}]" if b.impls else ""
                print(f"  bench  {b.name}{ab}")
            for m in suite.metrics.values():
                tol = "exact" if m.direction == "exact" else \
                    f"{m.direction}, {m.tolerance or 'default'}x"
                agg = ", aggregate" if m.aggregate else ""
                print(f"  metric {m.name} ({m.scope}; {tol}{agg})")
        return

    import jax
    names = sorted(_SUITES) if args.all else args.suites
    if not names:
        raise SystemExit("no suites named (or pass --all)")
    for name in names:
        suite = get_suite(name)
        if suite.needs_devices > len(jax.devices()):
            print(f"[{name}] SKIPPED: needs {suite.needs_devices} devices, "
                  f"{len(jax.devices())} visible (set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count="
                  f"{suite.needs_devices})")
            continue
        record, path = run_suite(name, fast=args.fast, out=args.out,
                                 append_history=args.history)
        print(f"[{name}] wrote {path} "
              f"({len(record.get('grid', {}))} grid cells)")


if __name__ == "__main__":
    # `python -m benchmarks.registry` runs this file as __main__ while the
    # suite modules import (and register into) the canonical
    # `benchmarks.registry` instance — delegate to that one.
    from benchmarks.registry import main as _canonical_main
    _canonical_main()

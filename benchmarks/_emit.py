"""Schema-versioned benchmark record emitter shared by every suite.

All ``BENCH_*.json`` perf artifacts (engine_bench's bytecode / baselines /
shards records, hotpath_bench, dist_bench) go through
:func:`write_bench`, which stamps each payload with:

* ``schema_rev`` — bumped whenever a suite changes the meaning or layout
  of its fields, so ``benchmarks/check_regression.py`` (and any external
  consumer of the CI artifacts) can refuse records it does not
  understand instead of comparing incompatible numbers;
* ``suite`` — which generator produced it;
* ``env`` — the jax/python versions and the device platform+count the
  numbers were measured on (CPU wall-clock comparisons are only
  meaningful within a platform);
* ``run`` — the run metadata: ``mode`` (``fast`` / ``full``) and the grid
  parameters the suite actually measured with.  Aggregate metrics
  (medians over the grid) are only meaningful between runs over the SAME
  cell set, so the regression gate refuses to compare aggregates across
  differing run metadata (:class:`IncomparableRunsError`) instead of
  silently comparing medians over different grids.

No wall-clock timestamp: records are committed at the repo root, and the
measured fields are the only diff a regeneration should show.
"""
from __future__ import annotations

import json
import os
import platform
import sys
from typing import Any, Mapping

#: Bump when any suite's record layout changes incompatibly.
#: rev 3: records carry ``run`` metadata (mode + grid params) and every
#: suite is emitted through ``benchmarks.registry.run_suite``.
SCHEMA_REV = 3


class IncomparableRunsError(ValueError):
    """Two records whose aggregate metrics must not be compared: they were
    measured under different run metadata (``--fast`` vs ``--full``, or
    different grid parameters), so grid-wide aggregates like
    ``median_update_vs_build_x`` would be medians over different cell
    sets.  Regenerate one side with the other's mode instead."""

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _env_stamp() -> dict:
    import jax
    devices = jax.devices()
    return {
        "jax": jax.__version__,
        "python": platform.python_version(),
        "platform": devices[0].platform if devices else "unknown",
        "device_count": len(devices),
    }


def bench_path(name: str, out: str | None = None) -> str:
    """Where suite ``name``'s record lives: ``BENCH_<name>.json`` at the
    repo root, or under/at ``out`` when given (CI writes fresh records to a
    scratch path so the committed baseline stays comparable)."""
    filename = f"BENCH_{name}.json"
    if out is None:
        return os.path.join(_REPO_ROOT, filename)
    return os.path.join(out, filename) if os.path.isdir(out) else out


def write_bench(name: str, payload: Mapping[str, Any],
                out: str | None = None, mode: str | None = None,
                params: Mapping[str, Any] | None = None) -> str:
    """Write one suite's record; returns the path written.

    ``mode`` / ``params`` stamp the run metadata (``record["run"]``) —
    which cell set the numbers were measured over.  Callers going through
    ``benchmarks.registry.run_suite`` always stamp both; a record written
    without them carries ``mode="unknown"`` and can never satisfy the
    aggregate-comparison guard against a stamped record."""
    record = dict(payload)
    record["suite"] = name
    record["schema_rev"] = SCHEMA_REV
    record["env"] = _env_stamp()
    record["run"] = {"mode": mode or "unknown",
                     "params": dict(params or {})}
    path = bench_path(name, out)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_bench(path: str, expect_suite: str | None = None) -> dict:
    """Load a record, enforcing the schema handshake."""
    with open(path) as f:
        record = json.load(f)
    rev = record.get("schema_rev")
    if rev != SCHEMA_REV:
        raise ValueError(
            f"{path}: schema_rev {rev!r} != emitter {SCHEMA_REV} — "
            f"regenerate the record (make bench-{record.get('suite', '?')})")
    if expect_suite is not None and record.get("suite") != expect_suite:
        raise ValueError(f"{path}: suite {record.get('suite')!r}, "
                         f"expected {expect_suite!r}")
    return record


def main() -> None:
    """Print the env stamp (handy for CI debugging)."""
    print(json.dumps({"schema_rev": SCHEMA_REV, "env": _env_stamp()},
                     indent=2))


if __name__ == "__main__":
    main()

"""End-to-end training example: train a ~100M-param gemma-family model.

Full driver path: deterministic data pipeline -> jitted train step (grad
accumulation + AdamW) -> async checkpointing -> restart-safe resume.  On CPU
this is slow at 100M; pass --tiny for a quick smoke run (default), or
--full-100m for the real thing.

  PYTHONPATH=src python examples/train_lm.py            # tiny, ~1 min
  PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300
"""
import argparse
import sys

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args, _ = ap.parse_known_args()

    if args.full_100m:
        # ~100M params: 12 layers x d_model 640 over the gemma-2b family
        # (GeGLU + MQA), vocab 32000.
        steps = args.steps or 300
        argv = ["--arch", "gemma-2b", "--reduced",
                "--layers", "12", "--d-model", "640",
                "--steps", str(steps), "--batch", "8", "--seq", "512",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
                "--log-every", "10"]
    else:
        steps = args.steps or 30
        argv = ["--arch", "gemma-2b", "--reduced",
                "--layers", "4", "--d-model", "128",
                "--steps", str(steps), "--batch", "8", "--seq", "128",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "10",
                "--log-every", "5"]
    return train_driver.main(argv)


if __name__ == "__main__":
    sys.exit(main())

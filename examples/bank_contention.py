"""Paper Figure 4/7 analogue: Block-STM behavior across contention levels.

Sweeps the account count (2 = fully sequential ... 10k = embarrassingly
parallel) and prints the abort/incarnation profile plus measured CPU
throughput vs the sequential baseline.

  PYTHONPATH=src python examples/bank_contention.py
"""
import time

import numpy as np

from repro.core import workloads as W
from repro.core.engine import make_executor
from repro.core.vm import run_sequential


def main():
    n_txns = 512
    print(f"{'accounts':>9} {'waves':>6} {'exec/txn':>9} {'dep_ab':>7} "
          f"{'val_ab':>7} {'engine_tps':>11} {'seq_tps':>9} {'speedup':>8}")
    for accounts in (2, 10, 100, 1000, 10000):
        spec = W.P2PSpec(n_accounts=accounts)
        cfg = W.p2p_engine_config(spec, n_txns, window=32)
        run = make_executor(W.p2p_program(spec), cfg)
        params, storage = W.make_p2p_block(spec, n_txns, seed=0)
        res = run(params, storage)          # warm/compile
        res.snapshot.block_until_ready()
        t0 = time.perf_counter()
        res = run(params, storage)
        res.snapshot.block_until_ready()
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        expected = run_sequential(W.p2p_program(spec), params, storage,
                                  n_txns)
        dt_seq = time.perf_counter() - t0
        assert np.array_equal(np.asarray(res.snapshot), expected)
        print(f"{accounts:>9} {int(res.waves):>6} "
              f"{int(res.execs)/n_txns:>9.2f} {int(res.dep_aborts):>7} "
              f"{int(res.val_aborts):>7} {n_txns/dt:>11.0f} "
              f"{n_txns/dt_seq:>9.0f} {dt_seq/dt:>8.2f}")
    print("\n(2 accounts = inherently sequential: the engine degrades "
          "gracefully; 10k accounts = conflict-free: ~1 incarnation/txn, "
          "matching paper §4.1.)")


if __name__ == "__main__":
    main()

"""Serving example: Block-STM transactional admission + batched decode.

Each serving round:
  1. a block of request transactions (KV-page allocation from a shared
     free-list + tenant quota charge) executes in parallel under Block-STM —
     the outcome is bit-identical to sequential admission in arrival order,
     so every data-parallel replica independently reaches the same admission
     decision with no coordination traffic;
  2. admitted sequences run batched decode steps on the model.

  PYTHONPATH=src python examples/serve_blockstm.py
"""
import sys

from repro.launch import serve as serve_driver


def main():
    return serve_driver.main(["--arch", "gemma-2b", "--rounds", "3",
                              "--requests", "32", "--batch", "4",
                              "--max-seq", "32", "--decode-steps", "6"])


if __name__ == "__main__":
    sys.exit(main())

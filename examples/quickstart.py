"""Quickstart: execute a block of p2p transactions with Block-STM.

Demonstrates the public API end-to-end:
  * define a transaction program (reads/writes via the ctx),
  * build an engine config + jitted executor,
  * run the block, verify against the sequential oracle,
  * inspect the paper's scheduler statistics.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import EngineConfig, make_executor, run_sequential
from repro.core import workloads as W


def main():
    # A block of 256 payments over 100 accounts (moderate contention).
    spec = W.P2PSpec(n_accounts=100)
    n_txns = 256
    params, storage = W.make_p2p_block(spec, n_txns, seed=42)

    cfg = W.p2p_engine_config(spec, n_txns, window=32)
    execute = make_executor(W.p2p_program(spec), cfg)

    result = execute(params, storage)
    assert bool(result.committed)

    expected = run_sequential(W.p2p_program(spec), params, storage, n_txns)
    assert np.array_equal(np.asarray(result.snapshot), expected), \
        "parallel != sequential (impossible: see tests)"

    print("Block-STM executed", n_txns, "txns over", spec.n_accounts,
          "accounts")
    print(f"  waves (BSP rounds)     : {int(result.waves)}")
    print(f"  incarnations executed  : {int(result.execs)} "
          f"({int(result.execs)/n_txns:.2f} per txn)")
    print(f"  dependency aborts      : {int(result.dep_aborts)} "
          f"(ESTIMATE hits, paper §2)")
    print(f"  validation aborts      : {int(result.val_aborts)}")
    print(f"  wrote-new-location     : {int(result.wrote_new)}")
    print("  snapshot == sequential : True")

    # a custom transaction program in five lines:
    def transfer_all(p, ctx):
        bal = ctx.read(p["src"])
        ctx.write(p["src"], bal - bal, enabled=bal > 0)
        dst = ctx.read(p["dst"])
        ctx.write(p["dst"], dst + bal, enabled=bal > 0)

    import jax.numpy as jnp
    cfg2 = EngineConfig(n_txns=3, n_locs=4, max_reads=2, max_writes=2,
                        window=3)
    prm = {"src": jnp.asarray([0, 1, 2]), "dst": jnp.asarray([1, 2, 3])}
    st = jnp.asarray([5, 0, 0, 0], jnp.int32)
    res = make_executor(transfer_all, cfg2)(prm, st)
    print("custom chain-transfer snapshot:", np.asarray(res.snapshot),
          "(5 moved 0->1->2->3 sequentially-equivalently)")


if __name__ == "__main__":
    main()

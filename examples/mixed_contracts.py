"""Heterogeneous blocks through ONE compiled executor (bytecode VM demo).

The Python-DSL engine vmaps a single traced contract over the block: every new
contract type costs an XLA recompile, and a block can only hold one type.
The bytecode VM makes programs *data* — each transaction carries its own
``(code, args)`` — so a single jitted executor serves p2p payments, pointer-
chasing contracts, and serving-admission transactions mixed in one block, at
any ratio, with zero recompiles.  That is the compile-once path a production
validator (or serving gateway) needs: contract mix shifts with traffic, the
executable never changes.

  PYTHONPATH=src python examples/mixed_contracts.py
"""
import time

import numpy as np

from repro.bytecode import compile as BC
from repro.core import workloads as W
from repro.core.engine import make_executor
from repro.core.vm import run_sequential


def main():
    n_txns = 256
    spec = W.MixedSpec()

    print("== the three contract families, compiled to bytecode ==")
    adm = BC.compile_admission(spec.admission,
                               loc_base=spec.p2p.n_locs + spec.indirect.n_locs)
    print(f"admission contract ({adm.code.shape[0]} ops, "
          f"{adm.n_regs} regs, {adm.n_reads}R/{adm.n_writes}W):")
    print(adm.disassemble())
    print()

    # ONE executor, compiled ONCE, for every mix that follows.
    vm, params, storage, cfg = W.make_mixed_block(spec, n_txns, seed=0)
    run = make_executor(vm, cfg)
    t0 = time.perf_counter()
    run(params, storage).snapshot.block_until_ready()
    print(f"compiled the block executor once: {time.perf_counter()-t0:.2f}s\n")

    print(f"{'mix (p2p:ind:adm)':>20} {'waves':>6} {'exec/txn':>9} "
          f"{'tps':>8} {'ok':>3}")
    for ratios in [(1, 1, 1), (8, 1, 1), (1, 8, 1), (1, 1, 8), (0, 1, 0)]:
        vm_, params, storage, cfg_ = W.make_mixed_block(
            W.MixedSpec(ratios=ratios), n_txns, seed=sum(ratios))
        assert cfg_ == cfg      # same static shapes => same compiled program
        t0 = time.perf_counter()
        res = run(params, storage)
        res.snapshot.block_until_ready()
        dt = time.perf_counter() - t0
        expected = run_sequential(vm, params, storage, n_txns)
        ok = np.array_equal(np.asarray(res.snapshot), expected)
        print(f"{str(ratios):>20} {int(res.waves):>6} "
              f"{int(res.execs)/n_txns:>9.2f} {n_txns/dt:>8.0f} "
              f"{'✓' if ok else '✗':>3}")

    cache = run._cache_size() if hasattr(run, "_cache_size") else "?"
    print(f"\njit cache entries after 6 blocks / 5 mixes: {cache} "
          f"(zero recompiles — programs are data)")


if __name__ == "__main__":
    main()
